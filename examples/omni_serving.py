"""End-to-end driver: serve a Thinker->Talker->Vocoder any-to-any pipeline
(Qwen-Omni style, paper Fig 4) with batched requests and streaming synthesis,
and compare against the monolithic HF-style baseline.

  PYTHONPATH=src python examples/omni_serving.py [--requests 8]
"""
import argparse
import time

import jax
import numpy as np

from repro.baselines.monolithic import MonolithicQwenOmni
from repro.configs.pipelines import build_qwen_omni
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.models.dit import DiTConfig, init_dit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--thinker-tokens", type=int, default=10)
    ap.add_argument("--talker-tokens", type=int, default=40)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 500, size=int(rng.integers(8, 24))
                            ).astype(np.int32) for _ in range(args.requests)]

    # ---------------- disaggregated serving (this work) ----------------
    graph, engines, bundle = build_qwen_omni(
        max_batch=4, thinker_tokens=args.thinker_tokens,
        talker_tokens=args.talker_tokens, stream_chunk=8, dit_steps=4)
    orch = Orchestrator(graph, engines)
    # warmup (jit)
    orch.submit(Request(inputs={"tokens": prompts[0]}))
    orch.run()
    t0 = time.perf_counter()
    reqs = [Request(inputs={"tokens": p}) for p in prompts]
    for r in reqs:
        orch.submit(r)
    orch.run()
    wall = time.perf_counter() - t0
    jcts = [r.jct for r in reqs]
    print(f"[disaggregated] {len(reqs)} requests in {wall:.2f}s | "
          f"mean JCT {np.mean(jcts):.3f}s | "
          f"stage busy {dict((k, round(v, 2)) for k, v in orch.stage_busy_times().items())}")
    for r in reqs[:2]:
        wavs = r.outputs["vocoder"]
        n = sum(c["latent"].shape[0] for c in wavs)
        print(f"  req {r.req_id}: text={r.data['thinker_tokens'][:6]}... "
              f"audio_frames={n} (streamed {len(wavs)} chunks)")

    # ---------------- monolithic baseline ------------------------------
    vcfg = DiTConfig(name="voc", num_layers=2, d_model=128, num_heads=4,
                     d_ff=256, in_dim=32, cond_dim=128, num_steps=4)
    mono = MonolithicQwenOmni(bundle, (vcfg, init_dit(vcfg,
                                                      jax.random.PRNGKey(9))),
                              dit_steps=4)
    mono.run(prompts[:1])                        # warmup
    res = mono.run(prompts)
    jct_m = float(np.mean([r["jct"] for r in res]))
    print(f"[monolithic]    mean JCT {jct_m:.3f}s")
    print(f"JCT reduction: {100 * (1 - np.mean(jcts) / jct_m):.1f}% "
          f"(paper reports up to 91.4% for Qwen3-Omni)")


if __name__ == "__main__":
    main()
