"""Quickstart: define a two-stage any-to-any pipeline with the stage-graph
API and serve a few requests.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.pipelines import _kv, tiny_lm
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def main():
    # 1) models: a "planner" LM whose hidden states condition a "writer" LM
    planner_cfg = tiny_lm("planner", vocab=512)
    writer_cfg = tiny_lm("writer", vocab=512)
    planner_params = T.init_params(planner_cfg, jax.random.PRNGKey(0))
    writer_params = T.init_params(writer_cfg, jax.random.PRNGKey(1))

    # 2) engines: one per stage, independently configured (paper Fig 3(c))
    planner = AREngine("planner", planner_cfg, planner_params,
                       kv=_kv(4), max_batch=4, collect_hidden=True,
                       default_sampling=SamplingParams(max_new_tokens=8,
                                                       temperature=0.0))
    writer = AREngine("writer", writer_cfg, writer_params,
                      kv=_kv(4), max_batch=4,
                      default_sampling=SamplingParams(max_new_tokens=16,
                                                      temperature=0.7,
                                                      top_k=20))

    # 3) stage graph: nodes = stages, edges = transfer functions (Fig 3(b))
    graph = StageGraph()
    graph.add_stage(StageSpec("planner", "ar"))
    graph.add_stage(StageSpec("writer", "ar", is_output=True))
    graph.add_edge("planner", "writer",
                   lambda data, payload: {"prompt_embeds": payload["hidden"]},
                   connector="shm")

    # 4) serve
    orch = Orchestrator(graph, engines={"planner": planner, "writer": writer})
    rng = np.random.default_rng(0)
    for _ in range(4):
        orch.submit(Request(
            inputs={"tokens": rng.integers(0, 500, size=10).astype(np.int32)}))
    for req in orch.run():
        toks = req.outputs["writer"][0]["tokens"]
        print(f"req {req.req_id}: jct={req.jct:.3f}s "
              f"wrote {len(toks)} tokens: {toks[:8]}...")
    print("connector stats:", {k: (s.calls, s.bytes)
                               for k, s in orch.connector_stats().items()})


if __name__ == "__main__":
    main()
