"""GLM-Image style AR -> DiT pipeline: the LLM 'understands' the prompt and
emits VQ semantic tokens; a DiT decodes them into image latents.

  PYTHONPATH=src python examples/image_generation.py
"""
import numpy as np

from repro.configs.pipelines import build_ar_dit
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def main():
    graph, engines, bundle = build_ar_dit(
        "glm_image", max_batch=4, ar_tokens=16, image_latents=64,
        dit_steps=8, cache_interval=2)   # TeaCache-style reuse on
    orch = Orchestrator(graph, engines)
    rng = np.random.default_rng(0)
    for i in range(4):
        orch.submit(Request(
            inputs={"tokens": rng.integers(0, 500, size=12).astype(np.int32)}))
    for req in orch.run():
        latent = req.outputs["glm_image_dit"][0]["latent"]
        print(f"req {req.req_id}: jct={req.jct:.3f}s image latent "
              f"{latent.shape} (std={latent.std():.3f})")


if __name__ == "__main__":
    main()
