"""Train a reduced assigned-architecture config for a few hundred steps on
the synthetic data pipeline, with checkpointing — exercising the training
substrate end to end.

  PYTHONPATH=src python examples/train_tiny.py [--arch mixtral_8x7b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.data import TokenStream
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"training reduced {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)))
    ds = iter(TokenStream(cfg, batch=8, seq_len=64))
    first = None
    for i in range(1, args.steps + 1):
        b = next(ds)
        params, opt, m = step(params, opt, jnp.asarray(b["inputs"]),
                              jnp.asarray(b["labels"]))
        if first is None:
            first = float(m["loss"])
        if i % 25 == 0:
            print(f"step {i:4d} loss {float(m['loss']):.4f}")
    checkpoint.save("/tmp/train_tiny_ck.npz", params, opt, step=args.steps)
    p2, o2, s = checkpoint.load("/tmp/train_tiny_ck.npz", params, opt)
    print(f"final loss {float(m['loss']):.4f} (from {first:.4f}); "
          f"checkpoint round-trip ok at step {s}")


if __name__ == "__main__":
    main()
