"""Process-isolated stage replicas via ServeConfig.

A prefill→decode pipeline where the decode stage runs in spawned OS
processes: the child rebuilds its engine from a picklable EngineSpec,
prompt KV crosses the process boundary through the shared-memory
connector (named segments + manifests), and greedy outputs match the
all-thread run exactly.  Killing a process replica mid-run re-admits
its in-flight requests to the survivor — zero requests lost.

  PYTHONPATH=src python examples/process_isolation.py
"""
import numpy as np

from repro.configs.pipelines import build_pd_disaggregated
from repro.core.config import ServeConfig, StageConfig
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def main():
    # 1) a pipeline bundle: every builder attaches picklable
    #    `engine_specs` ("module:callable" + kwargs) alongside the live
    #    engines — specs are the only engine form that can cross a
    #    spawn boundary (deterministic builders, same seed → same params)
    graph, engines, bundle = build_pd_disaggregated(max_batch=4, max_new=8)

    # 2) one typed config for the whole serving surface: decode runs as
    #    2 spawned process replicas, prefill stays a thread
    config = ServeConfig(
        routing="affinity",
        stages={"decode": StageConfig(
            replicas=2,
            isolation="process",
            engine_spec=bundle["engine_specs"]["decode"])})

    orch = Orchestrator(graph, engines, config=config)
    orch.start()                         # spawn now, before timing anything

    # 3) serve: prompt KV travels prefill→decode through the shm
    #    connector — cross_process=True ships segment manifests, so the
    #    decode child attaches the same named segment the prefill thread
    #    wrote (one copy, no pickling of the KV arrays)
    rng = np.random.default_rng(0)
    reqs = [Request(inputs={"tokens":
                            rng.integers(0, 500, size=n).astype(np.int32)})
            for n in (5, 19, 33, 12)]
    for r in reqs:
        orch.submit(r)
    for req in orch.run(timeout=300.0):
        toks = req.outputs["decode"][0]["tokens"]
        print(f"req {req.req_id}: jct={req.jct:.3f}s "
              f"tokens={[int(t) for t in toks]}")

    # 4) the process replicas report the same metrics as threads —
    #    WorkerMetrics snapshots ride the control pipe home
    m = orch.stage_metrics()["decode"]
    print(f"decode: finished={m['finished']} n_replicas={m['n_replicas']} "
          f"replica_failures={m['replica_failures']}")


if __name__ == "__main__":
    main()
