#!/usr/bin/env python
"""Repo lint gate (``make lint``).

Prefers ruff when it is installed (pinned rule set below, so results
don't drift with ruff's defaults).  Offline images don't ship ruff, so
there is a built-in fallback that enforces the subset of those rules we
rely on repo-wide:

  * the file parses (syntax errors),
  * no unused ``import`` / ``from .. import`` names (F401),
  * no trailing whitespace (W291/W293) and no tab indentation (W191),
  * lines at most MAX_LINE chars (E501),
  * file ends with exactly one trailing newline (W292/W391).

Both paths lint the same tree and exit non-zero on any finding, so
``make check`` behaves identically with or without ruff.

On top of either path, a repo-specific deprecation scan ALWAYS runs
(ruff cannot know about these):

  * DEP001 — connector ``put()/get()/delete()`` trio (use the channel
    API: ``send()/recv()/release()``),
  * DEP002 — the ``Orchestrator(replicas=..., routing=..., ...)``
    kwargs bag (build a ``ServeConfig`` and pass ``config=...``).

A ``# noqa`` on the offending line opts out (the shim tests do this
deliberately).

  python tools/lint.py [paths...]
"""
from __future__ import annotations

import ast
import pathlib
import shutil
import subprocess
import sys
from typing import Iterator, List

MAX_LINE = 100
# pinned ruff rules: keep in lockstep with the fallback checks above
RUFF_ARGS = ["check", "--select", "E501,F401,F63,F7,F82,W191,W291,W292,W293",
             "--line-length", str(MAX_LINE)]
DEFAULT_PATHS = ["src", "tests", "benchmarks", "tools", "examples"]
REPO = pathlib.Path(__file__).resolve().parent.parent


def iter_py(paths: List[str]) -> Iterator[pathlib.Path]:
    for p in paths:
        root = REPO / p
        if root.is_file() and root.suffix == ".py":
            yield root
        elif root.is_dir():
            yield from sorted(root.rglob("*.py"))


def _imported_names(tree: ast.Module) -> List[tuple]:
    """(lineno, bound_name, display_name) for every import binding."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                out.append((node.lineno, bound, a.name))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bound = a.asname or a.name
                out.append((node.lineno, bound, a.name))
    return out


def _used_names(tree: ast.Module) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # the root of a dotted use (np.foo -> np) is a Name and is
            # picked up above; nothing extra needed here
            pass
        # names re-exported via __all__ count as used
        elif (isinstance(node, ast.Assign) and node.targets
              and isinstance(node.targets[0], ast.Name)
              and node.targets[0].id == "__all__"):
            try:
                used.update(ast.literal_eval(node.value))
            except (ValueError, SyntaxError):
                pass
    return used


# deprecated surfaces (see src/repro/connector/base.py and
# src/repro/core/orchestrator.py): keep in lockstep with the runtime
# DeprecationWarnings so the lint gate and the warnings retire together
_DEP_CONNECTOR_TRIO = {"put", "get", "delete"}
_DEP_ORCH_KWARGS = {"queue_capacity", "recv_timeout", "replicas", "routing",
                    "engine_factories", "engine_specs", "isolation",
                    "warm_seed"}          # bare backend= predates the bag


def _looks_like_connector(node: ast.expr) -> bool:
    """Receiver heuristic for DEP001: a name (or attribute) that says
    it holds a connector — ``conn``, ``connector``, ``seed_connector``.
    Keeps dict ``.get()`` / set ``.delete()`` lookalikes out."""
    name = None
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    return name is not None and "conn" in name.lower()


def scan_deprecated(path: pathlib.Path, tree: ast.Module,
                    lines: List[str]) -> List[str]:
    rel = path.relative_to(REPO)
    errors: List[str] = []

    def flagged(lineno: int) -> bool:
        return "noqa" in lines[lineno - 1]

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if (isinstance(fn, ast.Attribute) and fn.attr in _DEP_CONNECTOR_TRIO
                and _looks_like_connector(fn.value)
                and not flagged(node.lineno)):
            errors.append(
                f"{rel}:{node.lineno}: DEP001 connector .{fn.attr}() is "
                f"deprecated; use the channel API "
                f"(send()/recv()/release())")
        if (isinstance(fn, ast.Name) and fn.id == "Orchestrator"):
            for kw in node.keywords:
                if (kw.arg in _DEP_ORCH_KWARGS
                        and not flagged(kw.value.lineno)):
                    errors.append(
                        f"{rel}:{kw.value.lineno}: DEP002 Orchestrator "
                        f"kwargs bag ({kw.arg}=...) is deprecated; pass "
                        f"config=ServeConfig(...)")
    return errors


def lint_file(path: pathlib.Path) -> List[str]:
    rel = path.relative_to(REPO)
    text = path.read_text()
    errors: List[str] = []
    try:
        tree = ast.parse(text, filename=str(rel))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: syntax error: {e.msg}"]

    lines = text.split("\n")
    for i, line in enumerate(lines, 1):
        if len(line) > MAX_LINE:
            errors.append(f"{rel}:{i}: E501 line too long "
                          f"({len(line)} > {MAX_LINE})")
        if line != line.rstrip():
            errors.append(f"{rel}:{i}: W291 trailing whitespace")
        if line[:1] == "\t" or line.lstrip(" ")[:1] == "\t":
            errors.append(f"{rel}:{i}: W191 tab indentation")
    if text and not text.endswith("\n"):
        errors.append(f"{rel}:{len(lines)}: W292 no newline at end of file")
    if text.endswith("\n\n"):
        errors.append(f"{rel}:{len(lines)}: W391 blank line at end of file")

    # F401: unused imports.  __init__.py re-exports are conventional;
    # a `# noqa` on the import line opts out explicitly.
    if path.name != "__init__.py":
        used = _used_names(tree)
        for lineno, bound, display in _imported_names(tree):
            if bound in used or bound == "_":
                continue
            if "noqa" in lines[lineno - 1]:
                continue
            errors.append(f"{rel}:{lineno}: F401 '{display}' imported "
                          "but unused")
    errors.extend(scan_deprecated(path, tree, lines))
    return errors


def deprecation_findings(paths: List[str]) -> List[str]:
    """The DEP scan alone — run alongside ruff, which can't know about
    repo-local deprecations (the fallback path folds it into lint_file)."""
    out: List[str] = []
    for f in iter_py(paths):
        text = f.read_text()
        try:
            tree = ast.parse(text, filename=str(f))
        except SyntaxError:
            continue                      # ruff reports the syntax error
        out.extend(scan_deprecated(f, tree, text.split("\n")))
    return out


def main(argv: List[str]) -> int:
    paths = argv or DEFAULT_PATHS
    ruff = shutil.which("ruff")
    if ruff:
        targets = [str(REPO / p) for p in paths if (REPO / p).exists()]
        rc = subprocess.call([ruff, *RUFF_ARGS, *targets])
        dep = deprecation_findings(paths)
        for e in dep:
            print(e)
        if dep:
            print(f"lint: {len(dep)} deprecation finding(s)")
        return 1 if (rc or dep) else 0
    errors: List[str] = []
    n = 0
    for f in iter_py(paths):
        n += 1
        errors.extend(lint_file(f))
    for e in errors:
        print(e)
    tool = "built-in fallback (ruff not installed)"
    print(f"lint: {n} files, {len(errors)} finding(s) [{tool}]")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
