#!/usr/bin/env python
"""Repo lint gate (``make lint``).

Prefers ruff when it is installed (pinned rule set below, so results
don't drift with ruff's defaults).  Offline images don't ship ruff, so
there is a built-in fallback that enforces the subset of those rules we
rely on repo-wide:

  * the file parses (syntax errors),
  * no unused ``import`` / ``from .. import`` names (F401),
  * no trailing whitespace (W291/W293) and no tab indentation (W191),
  * lines at most MAX_LINE chars (E501),
  * file ends with exactly one trailing newline (W292/W391).

Both paths lint the same tree and exit non-zero on any finding, so
``make check`` behaves identically with or without ruff.

The repo-specific rules that used to live here (DEP001/DEP002) moved to
the invariant analyzer — ``python -m tools.analyze`` / ``make analyze``
— alongside the concurrency and lifetime rules.  Suppression is
code-aware and shared with that framework: ``# noqa: F401`` silences
exactly F401 (a bare ``# noqa`` still silences everything; a marker
naming only other codes no longer does).

  python tools/lint.py [paths...]
"""
from __future__ import annotations

import ast
import pathlib
import shutil
import subprocess
import sys
from typing import Iterator, List

MAX_LINE = 100
# pinned ruff rules: keep in lockstep with the fallback checks above
RUFF_ARGS = ["check", "--select", "E501,F401,F63,F7,F82,W191,W291,W292,W293",
             "--line-length", str(MAX_LINE)]
DEFAULT_PATHS = ["src", "tests", "benchmarks", "tools", "examples"]
REPO = pathlib.Path(__file__).resolve().parent.parent

# the shared noqa parser lives in the analyzer framework; bootstrap the
# import so `python tools/lint.py` works from anywhere
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))
from tools.analyze.framework import is_suppressed  # noqa: E402


def iter_py(paths: List[str]) -> Iterator[pathlib.Path]:
    for p in paths:
        root = REPO / p
        if root.is_file() and root.suffix == ".py":
            yield root
        elif root.is_dir():
            yield from sorted(root.rglob("*.py"))


def _imported_names(tree: ast.Module) -> List[tuple]:
    """(lineno, bound_name, display_name) for every import binding."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                out.append((node.lineno, bound, a.name))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bound = a.asname or a.name
                out.append((node.lineno, bound, a.name))
    return out


def _used_names(tree: ast.Module) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # the root of a dotted use (np.foo -> np) is a Name and is
            # picked up above; nothing extra needed here
            pass
        # names re-exported via __all__ count as used
        elif (isinstance(node, ast.Assign) and node.targets
              and isinstance(node.targets[0], ast.Name)
              and node.targets[0].id == "__all__"):
            try:
                used.update(ast.literal_eval(node.value))
            except (ValueError, SyntaxError):
                pass
    return used


def lint_file(path: pathlib.Path) -> List[str]:
    rel = path.relative_to(REPO)
    text = path.read_text()
    errors: List[str] = []
    try:
        tree = ast.parse(text, filename=str(rel))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: syntax error: {e.msg}"]

    lines = text.split("\n")
    for i, line in enumerate(lines, 1):
        if len(line) > MAX_LINE:
            errors.append(f"{rel}:{i}: E501 line too long "
                          f"({len(line)} > {MAX_LINE})")
        if line != line.rstrip():
            errors.append(f"{rel}:{i}: W291 trailing whitespace")
        if line[:1] == "\t" or line.lstrip(" ")[:1] == "\t":
            errors.append(f"{rel}:{i}: W191 tab indentation")
    if text and not text.endswith("\n"):
        errors.append(f"{rel}:{len(lines)}: W292 no newline at end of file")
    if text.endswith("\n\n"):
        errors.append(f"{rel}:{len(lines)}: W391 blank line at end of file")

    # F401: unused imports.  __init__.py re-exports are conventional;
    # a `# noqa: F401` on the import line opts out explicitly.
    if path.name != "__init__.py":
        used = _used_names(tree)
        for lineno, bound, display in _imported_names(tree):
            if bound in used or bound == "_":
                continue
            if is_suppressed("F401", lines[lineno - 1]):
                continue
            errors.append(f"{rel}:{lineno}: F401 '{display}' imported "
                          "but unused")
    return errors


def main(argv: List[str]) -> int:
    paths = argv or DEFAULT_PATHS
    ruff = shutil.which("ruff")
    if ruff:
        targets = [str(REPO / p) for p in paths if (REPO / p).exists()]
        return 1 if subprocess.call([ruff, *RUFF_ARGS, *targets]) else 0
    errors: List[str] = []
    n = 0
    for f in iter_py(paths):
        n += 1
        errors.extend(lint_file(f))
    for e in errors:
        print(e)
    tool = "built-in fallback (ruff not installed)"
    print(f"lint: {n} files, {len(errors)} finding(s) [{tool}]")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
