"""Repo tooling: lint gate (tools/lint.py) and the repo-specific
static-analysis framework (tools/analyze)."""
