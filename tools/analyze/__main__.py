"""CLI for the invariant analyzer (``python -m tools.analyze``).

Exit status is non-zero iff any finding is neither ``# noqa``-suppressed
nor covered by the committed baseline.  When baseline entries have gone
stale (their findings were fixed), a compare.py-style trend line reports
the shrink so the baseline can be regenerated.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import List, Optional

from tools.analyze.framework import (BASELINE_PATH, DEFAULT_PATHS,
                                     Baseline, analyze_paths, RULES)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analyze",
        description="repo-specific invariant analyzer (see "
                    "tools/analyze/__init__.py for the rule codes)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", metavar="OUT.json", default=None,
                    help="dump all findings (new + baselined) as JSON")
    ap.add_argument("--baseline", metavar="FILE",
                    default=str(BASELINE_PATH),
                    help="baseline file (default: committed baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings, "
                         "keeping existing justifications")
    ap.add_argument("--select", metavar="CODES", default=None,
                    help="comma-separated rule codes to run")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    # import for side effect: rule modules register themselves
    from tools.analyze import deprecations, lifetime, locks, spawn  # noqa: F401
    if args.list_rules:
        for code in sorted(RULES):
            r = RULES[code]
            print(f"{code}  {r.name}: {r.summary}")
        return 0

    codes = (args.select.split(",") if args.select else None)
    findings = analyze_paths(args.paths or None, codes=codes)

    bl_path = pathlib.Path(args.baseline)
    baseline = (Baseline() if args.no_baseline
                else Baseline.load(bl_path))
    new, old, stale = baseline.split(findings)

    if args.update_baseline:
        baseline.rebuilt_from(findings).save(bl_path)
        print(f"analyze: baseline rewritten with {len(findings)} "
              f"entr{'y' if len(findings) == 1 else 'ies'} -> {bl_path}")
        return 0

    if args.json:
        payload = {
            "findings": [dict(f.to_json(), baselined=(f in old))
                         for f in findings],
            "counts": {"new": len(new), "baselined": len(old),
                       "stale_baseline": len(stale)},
        }
        pathlib.Path(args.json).write_text(
            json.dumps(payload, indent=2) + "\n")

    for f in new:
        print(f.render())
    n_files = len(set(f.file for f in findings)) if findings else 0
    print(f"analyze: {len(findings)} finding(s) "
          f"({len(old)} baselined, {len(new)} new"
          f"{f', across {n_files} files' if findings else ''})")
    if stale:
        kept = len(baseline.entries) - len(stale)
        print(f"analyze trend: baseline {len(baseline.entries)} -> "
              f"{kept} matched ({len(stale)} finding(s) fixed — run "
              f"--update-baseline to shrink it)")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
