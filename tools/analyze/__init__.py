"""Repo-specific static analysis: invariants as a machine-checked gate.

The disaggregated backend (per-stage workers, replica sets, process
isolation, connector-routed transfers) is genuinely concurrent, and its
correctness invariants used to live only in comments and
DeprecationWarnings.  This package checks them on every ``make check``.

Rule codes
----------

  CCY001  lock-discipline — fields annotated ``# guarded-by: _lock``
          (or ``# guarded-by-writes: _lock`` for the write-locked /
          lock-free-read PageAllocator pattern) must only be accessed
          inside ``with self._lock``; methods annotated
          ``# requires-lock: _lock`` must only be called with it held;
          read-modify-writes of a guarded field through another object
          are flagged wherever they appear.
  CCY002  lock-order — cycles in the static lock-acquisition graph
          (``with`` nesting plus intra-class call resolution), and
          re-entry on a non-reentrant ``threading.Lock``.
  CCY003  blocking-call-under-lock — no queue ``put/get``, ``join()``,
          ``time.sleep``, connector ``recv/send``, or engine ``step()``
          / prefix extraction inside a held-lock block (the warm-seed
          "no lock held during extraction" rule, machine-checked).
  RES001  connector-key-lifetime — every ``send()``/``recv()`` key flow
          must reach ``release()``/``read_and_release()`` in the same
          function or escape via a tracked handle / owner.
  PKL001  spawn-safety — no lambdas, closures, or function-local defs
          as ``EngineSpec`` targets or ``engine_factory`` values for
          ``isolation="process"`` stages.
  DEP001  deprecated connector ``put()/get()/delete()`` trio (migrated
          from tools/lint.py; use ``send()/recv()/release()``).
  DEP002  deprecated ``Orchestrator(**kwargs)`` bag (migrated from
          tools/lint.py; pass ``config=ServeConfig(...)``).

Suppression and baseline
------------------------

``# noqa: CODE`` on the offending line suppresses that code only
(``# noqa: CCY003, RES001`` for several; a bare ``# noqa`` suppresses
everything — prefer naming codes).  Grandfathered findings live in
``tools/analyze/baseline.json`` with a one-line justification each;
``python -m tools.analyze --update-baseline`` rewrites it from the
current findings, preserving justifications.  The gate exits non-zero
only on findings that are neither suppressed nor baselined, and prints
a shrink trend when baseline entries go stale.

Usage::

    python -m tools.analyze                  # repo-wide gate
    python -m tools.analyze src/repro/core   # subtree
    python -m tools.analyze --json OUT.json  # machine-readable dump
    python -m tools.analyze --list-rules
"""
from tools.analyze.framework import (Baseline, BaselineEntry, Finding,
                                     Rule, RULES, analyze_paths,
                                     analyze_source, is_suppressed,
                                     noqa_codes, register)

__all__ = ["Baseline", "BaselineEntry", "Finding", "Rule", "RULES",
           "analyze_paths", "analyze_source", "is_suppressed",
           "noqa_codes", "register"]
