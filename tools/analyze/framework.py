"""Core of the repo-specific static-analysis framework.

Pieces (everything else in :mod:`tools.analyze` builds on these):

  - :class:`Finding` — one diagnostic, keyed for baseline matching by
    ``(file, code, stripped source line)`` so entries survive line-number
    drift from unrelated edits.
  - code-aware suppression — ``is_suppressed(code, line)`` implements
    flake8 ``noqa`` semantics: a bare ``# noqa`` silences every code on
    the line, ``# noqa: CODE1,CODE2`` silences exactly those codes, and
    anything else (``# noqa: BLE001 — fault isolation``) silences only
    the codes it names.  This replaces the old bare-substring match that
    let an unrelated ruff suppression swallow repo rules too.
  - :class:`Rule` + :func:`register` — the rule registry.  A rule's
    ``check(ctx, corpus)`` sees one file plus a corpus handle with a
    shared cache, so multi-file passes (class inheritance, the lock
    graph) are built once and reused.
  - :class:`Baseline` — committed grandfather file
    (``tools/analyze/baseline.json``): findings matching an entry are
    reported separately and do not fail the gate; stale entries (fixed
    findings) are surfaced as a shrink trend.

Run it with ``python -m tools.analyze`` (see ``__main__.py``).
"""
from __future__ import annotations

import ast
import json
import pathlib
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

REPO = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_PATHS = ["src", "tests", "benchmarks", "tools", "examples"]
BASELINE_PATH = pathlib.Path(__file__).resolve().parent / "baseline.json"

# ---------------------------------------------------------------------------
# suppression (code-aware noqa)
# ---------------------------------------------------------------------------

_NOQA_RE = re.compile(
    r"#\s*noqa(?P<colon>:\s*(?P<codes>[A-Z]+[0-9]+"
    r"(?:\s*,\s*[A-Z]+[0-9]+)*))?", re.IGNORECASE)


def noqa_codes(line: str) -> Optional[frozenset]:
    """Parse the ``noqa`` marker on one source line.

    Returns None when there is no marker, an empty frozenset for a bare
    ``# noqa`` (suppress everything), or the set of named codes.
    """
    m = _NOQA_RE.search(line)
    if m is None:
        return None
    codes = m.group("codes")
    if codes is None:
        return frozenset()
    return frozenset(c.strip().upper() for c in codes.split(","))


def is_suppressed(code: str, line: str) -> bool:
    """True when ``line`` carries a noqa that silences ``code``."""
    codes = noqa_codes(line)
    if codes is None:
        return False
    return not codes or code.upper() in codes


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Finding:
    """One diagnostic.  ``source`` is the stripped text of the flagged
    line — the stable part of the baseline key."""
    file: str                 # repo-relative posix path
    line: int
    code: str
    message: str
    source: str = ""

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.code} {self.message}"

    def key(self) -> Tuple[str, str, str]:
        return (self.file, self.code, self.source)

    def to_json(self) -> Dict[str, Any]:
        return {"file": self.file, "line": self.line, "code": self.code,
                "message": self.message, "source": self.source}


# ---------------------------------------------------------------------------
# file context + corpus
# ---------------------------------------------------------------------------

class FileContext:
    """One parsed source file plus the helpers every rule needs."""

    def __init__(self, path: pathlib.Path, text: Optional[str] = None,
                 rel: Optional[str] = None):
        self.path = path
        self.text = path.read_text() if text is None else text
        self.lines = self.text.split("\n")
        if rel is None:
            try:
                rel = path.resolve().relative_to(REPO).as_posix()
            except ValueError:        # outside the repo (tests, tmp dirs)
                rel = path.as_posix()
        self.rel = rel
        self.tree: Optional[ast.Module] = None
        self.syntax_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(self.text, filename=self.rel)
        except SyntaxError as e:
            self.syntax_error = e

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, lineno: int, code: str, message: str) -> Finding:
        return Finding(self.rel, lineno, code, message,
                       source=self.line_text(lineno).strip())


class Corpus:
    """All files of one analysis run plus a shared cache for passes that
    need a cross-file view (class registry, lock graph)."""

    def __init__(self, contexts: Sequence[FileContext]):
        self.contexts = list(contexts)
        self.cache: Dict[str, Any] = {}


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------

class Rule:
    """One registered rule.  Subclasses set ``code``/``name``/``summary``
    and implement ``check``; findings on lines carrying a matching
    ``# noqa: CODE`` are dropped by the runner, not the rule."""

    code = "XXX000"
    name = "unnamed"
    summary = ""

    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        raise NotImplementedError


RULES: Dict[str, Rule] = {}


def register(rule_cls):
    """Class decorator: instantiate and add to the registry."""
    RULES[rule_cls.code] = rule_cls()
    return rule_cls


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

@dataclass
class BaselineEntry:
    file: str
    code: str
    source: str
    justification: str = ""
    line: int = 0                    # informational only (drifts)

    def key(self) -> Tuple[str, str, str]:
        return (self.file, self.code, self.source)


@dataclass
class Baseline:
    entries: List[BaselineEntry] = field(default_factory=list)

    @classmethod
    def load(cls, path: pathlib.Path = BASELINE_PATH) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        return cls([BaselineEntry(**e) for e in data.get("entries", [])])

    def save(self, path: pathlib.Path = BASELINE_PATH) -> None:
        data = {"entries": [vars(e) for e in self.entries]}
        path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")

    def split(self, findings: Sequence[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[BaselineEntry]]:
        """(new, baselined, stale_entries).  Matching is multiset-aware:
        N entries with one key absorb at most N findings with that key."""
        budget: Dict[Tuple[str, str, str], int] = {}
        for e in self.entries:
            budget[e.key()] = budget.get(e.key(), 0) + 1
        new: List[Finding] = []
        old: List[Finding] = []
        for f in findings:
            if budget.get(f.key(), 0) > 0:
                budget[f.key()] -= 1
                old.append(f)
            else:
                new.append(f)
        stale = []
        seen: Dict[Tuple[str, str, str], int] = {}
        for e in self.entries:
            seen[e.key()] = seen.get(e.key(), 0) + 1
            if seen[e.key()] > sum(1 for f in old if f.key() == e.key()):
                stale.append(e)
        return new, old, stale

    def rebuilt_from(self, findings: Sequence[Finding]) -> "Baseline":
        """A fresh baseline holding exactly ``findings``, keeping the
        justification of any entry whose key survives."""
        just = {e.key(): e.justification for e in self.entries}
        return Baseline([
            BaselineEntry(f.file, f.code, f.source,
                          justification=just.get(
                              f.key(), "TODO: justify or fix"),
                          line=f.line)
            for f in findings])


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def iter_py(paths: Sequence[str]) -> Iterator[pathlib.Path]:
    for p in paths:
        root = pathlib.Path(p)
        if not root.is_absolute():
            root = REPO / p
        if root.is_file() and root.suffix == ".py":
            yield root
        elif root.is_dir():
            yield from sorted(root.rglob("*.py"))


def analyze_contexts(contexts: Sequence[FileContext],
                     codes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the registered rules (optionally a subset of codes) over the
    given files; returns noqa-filtered findings in deterministic order."""
    # rule modules self-register on import
    from tools.analyze import deprecations, lifetime, locks, spawn  # noqa: F401
    corpus = Corpus(contexts)
    findings: List[Finding] = []
    for code in sorted(RULES):
        if codes is not None and code not in codes:
            continue
        rule = RULES[code]
        for ctx in corpus.contexts:
            if ctx.syntax_error is not None:
                continue              # the lint gate reports syntax errors
            for f in rule.check(ctx, corpus):
                if not is_suppressed(f.code, ctx.line_text(f.line)):
                    findings.append(f)
    findings.sort(key=lambda f: (f.file, f.line, f.code, f.message))
    return findings


def analyze_paths(paths: Optional[Sequence[str]] = None,
                  codes: Optional[Sequence[str]] = None) -> List[Finding]:
    contexts = [FileContext(p) for p in iter_py(paths or DEFAULT_PATHS)]
    return analyze_contexts(contexts, codes=codes)


def analyze_source(text: str, filename: str = "<memory>",
                   codes: Optional[Sequence[str]] = None) -> List[Finding]:
    """Analyze one in-memory source blob (the fixture-corpus tests)."""
    ctx = FileContext(pathlib.Path(filename), text=text, rel=filename)
    return analyze_contexts([ctx], codes=codes)
