"""PKL001 — spawn-safety for process-isolated stages.

``isolation="process"`` replicas are built in a spawned child from an
``EngineSpec`` whose ``target`` must name a module-level callable as a
``"module:callable"`` string — lambdas, closures, and function-local
defs cannot cross the pickle boundary (``core/config.py`` enforces the
string shape at runtime; this rule catches it at lint time, plus the
cases runtime validation cannot see until the child dies).

Flagged:

  - ``EngineSpec(lambda: ...)`` or ``EngineSpec(target=lambda: ...)``
  - ``EngineSpec("no_colon_here")`` — malformed target string
  - ``EngineSpec(local_fn)`` where ``local_fn`` is defined inside the
    enclosing function (a closure)
  - ``engine_factory=<lambda or local def>`` in any call that also
    passes ``isolation="process"`` (thread replicas may use closures;
    process replicas may not)
  - lambda values inside an ``engine_factories={...}`` /
    ``engine_specs={...}`` dict in an ``isolation="process"`` call
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from tools.analyze.framework import (Corpus, FileContext, Finding, Rule,
                                     register)
from tools.analyze.lifetime import _is_raises_with


def _callee_name(fn: ast.expr) -> Optional[str]:
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _is_process_isolated(call: ast.Call) -> bool:
    for kw in call.keywords:
        if (kw.arg == "isolation" and isinstance(kw.value, ast.Constant)
                and kw.value.value == "process"):
            return True
    return False


@register
class SpawnSafety(Rule):
    code = "PKL001"
    name = "spawn-safety"
    summary = ("lambda/closure/local used as an EngineSpec target or as "
               "engine_factory for an isolation=\"process\" stage")

    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        out: List[Finding] = []
        if ctx.tree is None:
            return out
        # names def'd inside each function scope (closure detection)
        local_defs: Dict[int, Set[str]] = {}
        parents: Dict[int, int] = {}

        def index(node: ast.AST, scope: Optional[ast.AST]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    if scope is not None:
                        local_defs.setdefault(id(scope),
                                              set()).add(child.name)
                    index(child, child)
                else:
                    index(child, scope)
                if scope is not None:
                    parents[id(child)] = id(scope)

        index(ctx.tree, None)

        # negative tests build deliberately-bad specs under pytest.raises
        exempt: Set[int] = set()
        for node in ast.walk(ctx.tree):
            if _is_raises_with(node):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Call):
                        exempt.add(id(sub))

        def enclosing_locals(call: ast.Call) -> Set[str]:
            names: Set[str] = set()
            sid = parents.get(id(call))
            while sid is not None:
                names |= local_defs.get(sid, set())
                sid = None          # one level is enough for the repo
            return names

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call) or id(node) in exempt:
                continue
            name = _callee_name(node.func)
            if name == "EngineSpec":
                out.extend(self._check_spec(ctx, node,
                                            enclosing_locals(node)))
            if _is_process_isolated(node):
                out.extend(self._check_process_call(
                    ctx, node, enclosing_locals(node)))
        return out

    def _check_spec(self, ctx: FileContext, call: ast.Call,
                    local_names: Set[str]) -> List[Finding]:
        target: Optional[ast.expr] = call.args[0] if call.args else None
        if target is None:
            for kw in call.keywords:
                if kw.arg == "target":
                    target = kw.value
        if target is None:
            return []
        if isinstance(target, ast.Lambda):
            return [ctx.finding(
                target.lineno, self.code,
                "EngineSpec target is a lambda; spawn targets must be "
                "module-level 'module:callable' strings")]
        if isinstance(target, ast.Constant) and isinstance(target.value,
                                                           str):
            if ":" not in target.value:
                return [ctx.finding(
                    target.lineno, self.code,
                    f"malformed EngineSpec target {target.value!r}; "
                    f"expected 'module:callable'")]
            return []
        if isinstance(target, ast.Name) and target.id in local_names:
            return [ctx.finding(
                target.lineno, self.code,
                f"EngineSpec target '{target.id}' is defined inside the "
                f"enclosing function; a spawned child cannot import a "
                f"closure")]
        return []

    def _check_process_call(self, ctx: FileContext, call: ast.Call,
                            local_names: Set[str]) -> List[Finding]:
        out: List[Finding] = []

        def bad_value(v: ast.expr, what: str) -> None:
            if isinstance(v, ast.Lambda):
                out.append(ctx.finding(
                    v.lineno, self.code,
                    f"{what} is a lambda but isolation=\"process\" "
                    f"requires a picklable EngineSpec"))
            elif isinstance(v, ast.Name) and v.id in local_names:
                out.append(ctx.finding(
                    v.lineno, self.code,
                    f"{what} '{v.id}' is a function-local closure but "
                    f"isolation=\"process\" requires a picklable "
                    f"EngineSpec"))

        for kw in call.keywords:
            if kw.arg == "engine_factory":
                bad_value(kw.value, "engine_factory")
            elif (kw.arg in ("engine_factories", "engine_specs")
                  and isinstance(kw.value, ast.Dict)):
                for v in kw.value.values:
                    bad_value(v, f"{kw.arg} value")
        return out
