"""Lock rules: CCY001 (discipline), CCY002 (order), CCY003 (blocking).

All three share one corpus-wide "lock pass" that builds a model of every
class: which attributes are locks (``threading.Lock/RLock/Condition``,
with ``Condition(self._lock)`` treated as an alias of ``_lock``), which
fields are annotated, and which methods assume a lock is already held.

Annotation convention (trailing comments, checked — not just docs):

  ``self._order = []          # guarded-by: _lock``
      every load and store of ``self._order`` must happen inside
      ``with self._lock`` (or inside a ``# requires-lock: _lock``
      method).  Read-modify-writes of the field through another object
      (``obj._order += ...``) are flagged wherever they appear.

  ``self._free = []           # guarded-by-writes: _lock``
      writes-only mode for the PageAllocator pattern: mutation needs the
      lock, but lock-free advisory reads are a documented contract.

  ``def _evict_one(self):  # requires-lock: _lock``
      the body runs with ``_lock`` held; callers must hold it, and the
      analyzer checks every ``self._evict_one()`` call site.

CCY001 checks field access against those annotations.  CCY002 builds a
static acquisition graph (``with`` nesting plus one level of intra-class
call resolution) and flags cycles and re-entry on non-reentrant
``threading.Lock``.  CCY003 flags calls that can block indefinitely
while a lock is held: ``time.sleep``, ``.join()``, queue ``put/get``,
connector ``recv/send``, engine ``step()`` / prefix extraction — the
"no lock held during KV extraction" warm-seed rule, machine-checked.
``Condition.wait`` on the held lock's own condition is exempt.

Known limits (by design — this is a lint, not a prover): lock tracking
is lexical and per-class; cross-object acquisition chains and locks
passed as arguments are not modeled.  Nested ``def``s are analyzed with
an empty held-set (they usually run later, on another thread); lambdas
inherit the enclosing held-set (they usually run inline).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from tools.analyze.framework import (Corpus, FileContext, Finding, Rule,
                                     register)

_GUARDED_RE = re.compile(r"#\s*guarded-by(?P<w>-writes)?:\s*(?P<lock>\w+)")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*(?P<lock>\w+)")

_LOCK_CTORS = {"Lock": "Lock", "RLock": "RLock", "Condition": "Condition"}

# list/dict/set methods that mutate their receiver: an annotated field
# used as the receiver of one of these counts as a write
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "popitem",
             "clear", "update", "setdefault", "add", "discard",
             "appendleft", "move_to_end"}

_QUEUEISH_RE = re.compile(
    r"(^|_)(q|queue|queues|inbox|outbox|completions|replies|cmd|evt|"
    r"events)s?$")


def _receiver_name(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _looks_like_connector(node: ast.expr) -> bool:
    name = _receiver_name(node)
    return name is not None and "conn" in name.lower()


def _is_self_attr(node: ast.expr) -> Optional[str]:
    """``self.X`` -> ``X``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


# ---------------------------------------------------------------------------
# class models
# ---------------------------------------------------------------------------

@dataclass
class ClassModel:
    name: str
    rel: str
    bases: List[str] = field(default_factory=list)
    lock_kinds: Dict[str, str] = field(default_factory=dict)
    aliases: Dict[str, str] = field(default_factory=dict)
    # field -> (lock attr, writes_only)
    guarded: Dict[str, Tuple[str, bool]] = field(default_factory=dict)
    requires: Dict[str, str] = field(default_factory=dict)
    methods: Dict[str, ast.AST] = field(default_factory=dict)

    def canon(self, attr: str) -> str:
        return self.aliases.get(attr, attr)

    def lock_of(self, node: ast.expr) -> Optional[str]:
        """Canonical lock attr when ``node`` is ``self.<lock>``."""
        attr = _is_self_attr(node)
        if attr is not None and attr in self.lock_kinds:
            return self.canon(attr)
        return None


_EMPTY = ClassModel(name="<module>", rel="")


def _base_names(node: ast.ClassDef) -> List[str]:
    out = []
    for b in node.bases:
        if isinstance(b, ast.Name):
            out.append(b.id)
        elif isinstance(b, ast.Attribute):
            out.append(b.attr)
    return out


def _build_class(ctx: FileContext, node: ast.ClassDef) -> ClassModel:
    cm = ClassModel(name=node.name, rel=ctx.rel, bases=_base_names(node))
    for item in node.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        cm.methods[item.name] = item
        # `# requires-lock: X` anywhere in the def signature lines
        sig_end = item.body[0].lineno if item.body else item.lineno
        for ln in range(item.lineno, sig_end + 1):
            m = _REQUIRES_RE.search(ctx.line_text(ln))
            if m:
                cm.requires[item.name] = m.group("lock")
                break
        for sub in ast.walk(item):
            if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                targets = (sub.targets if isinstance(sub, ast.Assign)
                           else [sub.target])
                attrs = [a for a in map(_is_self_attr, targets)
                         if a is not None]
                if not attrs:
                    continue
                value = sub.value
                # lock constructors and Condition(self._lock) aliases
                if isinstance(value, ast.Call):
                    fn = value.func
                    ctor = None
                    if isinstance(fn, ast.Attribute):
                        ctor = _LOCK_CTORS.get(fn.attr)
                    elif isinstance(fn, ast.Name):
                        ctor = _LOCK_CTORS.get(fn.id)
                    if ctor:
                        for a in attrs:
                            cm.lock_kinds[a] = ctor
                        if ctor == "Condition" and value.args:
                            target = _is_self_attr(value.args[0])
                            if target is not None:
                                for a in attrs:
                                    cm.aliases[a] = target
                # the annotation may trail any line of the statement, or
                # sit in the contiguous comment block directly above it
                cand = list(range(sub.lineno,
                                  (sub.end_lineno or sub.lineno) + 1))
                ln = sub.lineno - 1
                while ln >= 1 and ctx.line_text(ln).strip().startswith("#"):
                    cand.insert(0, ln)
                    ln -= 1
                for ln in cand:
                    m = _GUARDED_RE.search(ctx.line_text(ln))
                    if m:
                        spec = (m.group("lock"), m.group("w") is not None)
                        for a in attrs:
                            cm.guarded[a] = spec
                        break
    return cm


def _resolve(registry: Dict[str, ClassModel], name: str,
             seen: Optional[Set[str]] = None) -> ClassModel:
    """Merge a class with its (corpus-known) bases, subclass winning."""
    seen = seen or set()
    cm = registry[name]
    if not cm.bases or name in seen:
        return cm
    seen.add(name)
    merged = ClassModel(name=cm.name, rel=cm.rel, bases=cm.bases)
    for b in cm.bases:
        if b in registry and b not in seen:
            base = _resolve(registry, b, seen)
            merged.lock_kinds.update(base.lock_kinds)
            merged.aliases.update(base.aliases)
            merged.guarded.update(base.guarded)
            merged.requires.update(base.requires)
    merged.lock_kinds.update(cm.lock_kinds)
    merged.aliases.update(cm.aliases)
    merged.guarded.update(cm.guarded)
    merged.requires.update(cm.requires)
    merged.methods = cm.methods
    return merged


# ---------------------------------------------------------------------------
# per-method write classification
# ---------------------------------------------------------------------------

def _mark_target(t: ast.expr, writes: Set[int]) -> None:
    if isinstance(t, ast.Attribute):
        writes.add(id(t))
    elif isinstance(t, ast.Subscript):
        if isinstance(t.value, ast.Attribute):
            writes.add(id(t.value))        # self._owned[k] = v
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            _mark_target(e, writes)
    elif isinstance(t, ast.Starred):
        _mark_target(t.value, writes)


def _classify_writes(fn: ast.AST) -> Tuple[Set[int], Set[int]]:
    """(write_ids, rmw_ids): Attribute node ids that are written, and
    the subset that are read-modify-writes (AugAssign / mutator call)."""
    writes: Set[int] = set()
    rmw: Set[int] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                _mark_target(t, writes)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            _mark_target(node.target, writes)
        elif isinstance(node, ast.AugAssign):
            _mark_target(node.target, writes)
            _mark_target(node.target, rmw)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                _mark_target(t, writes)
                _mark_target(t, rmw)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr in _MUTATORS
              and isinstance(node.func.value, ast.Attribute)):
            writes.add(id(node.func.value))
            rmw.add(id(node.func.value))
    return writes, rmw


# ---------------------------------------------------------------------------
# the lock pass
# ---------------------------------------------------------------------------

@dataclass
class _Edge:
    src: Tuple[str, str]               # (class, lock)
    dst: Tuple[str, str]
    rel: str
    line: int
    dst_kind: str


class _LockPass:
    def __init__(self, corpus: Corpus):
        self.corpus = corpus
        self.registry: Dict[str, ClassModel] = {}
        # field name -> lock spec, for fields guarded in exactly one class
        self.unique_guarded: Dict[str, str] = {}
        self.findings: Dict[str, List[Finding]] = {}   # rel -> findings
        self.edges: List[_Edge] = []
        self._acq_memo: Dict[Tuple[str, str], Set[str]] = {}

    def emit(self, ctx: FileContext, lineno: int, code: str,
             msg: str) -> None:
        self.findings.setdefault(ctx.rel, []).append(
            ctx.finding(lineno, code, msg))

    # -- phase 1: collect ------------------------------------------------
    def collect(self) -> None:
        for ctx in self.corpus.contexts:
            if ctx.tree is None:
                continue
            for node in ctx.tree.body:
                if isinstance(node, ast.ClassDef):
                    self.registry[node.name] = _build_class(ctx, node)
        owners: Dict[str, Set[str]] = {}
        for cm in self.registry.values():
            for f in cm.guarded:
                owners.setdefault(f, set()).add(cm.name)
        for f, who in owners.items():
            if len(who) == 1:
                cls = self.registry[next(iter(who))]
                self.unique_guarded[f] = (
                    f"{cls.name}.{cls.guarded[f][0]}")

    # -- phase 2: walk ---------------------------------------------------
    def run(self) -> None:
        self.collect()
        for ctx in self.corpus.contexts:
            if ctx.tree is None:
                continue
            for node in ctx.tree.body:
                if isinstance(node, ast.ClassDef):
                    cls = _resolve(self.registry, node.name)
                    for m in node.body:
                        if isinstance(m, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                            self._walk_method(ctx, cls, m)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    self._walk_method(ctx, _EMPTY, node)
                else:
                    writes, rmw = _classify_writes(node)
                    self._walk(ctx, _EMPTY, "<module>", node,
                               frozenset(), writes, rmw)
        self._cycles()

    def _acquired(self, cls: ClassModel, mname: str,
                  stack: Optional[Set[str]] = None) -> Set[str]:
        """Canonical locks a method may acquire (with + self-calls)."""
        key = (cls.name, mname)
        if key in self._acq_memo:
            return self._acq_memo[key]
        stack = stack or set()
        if mname in stack or mname not in cls.methods:
            return set()
        stack.add(mname)
        out: Set[str] = set()
        for node in ast.walk(cls.methods[mname]):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    lk = cls.lock_of(item.context_expr)
                    if lk is not None:
                        out.add(lk)
            elif isinstance(node, ast.Call):
                callee = _is_self_attr(node.func)
                if callee is not None and callee in cls.methods:
                    out |= self._acquired(cls, callee, stack)
        self._acq_memo[key] = out
        return out

    def _walk_method(self, ctx: FileContext, cls: ClassModel,
                     fn: ast.AST) -> None:
        writes, rmw = _classify_writes(fn)
        held = frozenset()
        req = cls.requires.get(fn.name)
        if req is not None:
            held = frozenset({cls.canon(req)})
        in_init = fn.name in ("__init__", "__post_init__")
        for stmt in fn.body:
            self._walk(ctx, cls, fn.name, stmt, held, writes, rmw,
                       in_init=in_init)

    def _walk(self, ctx: FileContext, cls: ClassModel, mname: str,
              node: ast.AST, held: frozenset, writes: Set[int],
              rmw: Set[int], in_init: bool = False) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            new_held = set(held)
            for item in node.items:
                self._walk(ctx, cls, mname, item.context_expr, held,
                           writes, rmw, in_init)
                lk = cls.lock_of(item.context_expr)
                if lk is None:
                    continue
                for h in held:
                    self.edges.append(_Edge(
                        (cls.name, h), (cls.name, lk), ctx.rel,
                        item.context_expr.lineno,
                        cls.lock_kinds.get(lk, "Lock")))
                new_held.add(lk)
            for stmt in node.body:
                self._walk(ctx, cls, mname, stmt, frozenset(new_held),
                           writes, rmw, in_init)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: may run later on another thread; it cannot
            # assume the enclosing held-set
            for stmt in node.body:
                self._walk(ctx, cls, mname, stmt, frozenset(), writes,
                           rmw, in_init)
            return
        if isinstance(node, ast.Lambda):
            # lambdas (sort keys, cheap callbacks) usually run inline
            self._walk(ctx, cls, mname, node.body, held, writes, rmw,
                       in_init)
            return
        if isinstance(node, ast.Call):
            self._check_call(ctx, cls, mname, node, held, in_init)
        elif isinstance(node, ast.Attribute):
            self._check_attr(ctx, cls, mname, node, held, writes, rmw,
                             in_init)
        for child in ast.iter_child_nodes(node):
            self._walk(ctx, cls, mname, child, held, writes, rmw,
                       in_init)

    # -- CCY001: field discipline ---------------------------------------
    def _check_attr(self, ctx: FileContext, cls: ClassModel, mname: str,
                    node: ast.Attribute, held: frozenset,
                    writes: Set[int], rmw: Set[int],
                    in_init: bool) -> None:
        is_write = (id(node) in writes
                    or isinstance(node.ctx, (ast.Store, ast.Del)))
        attr = _is_self_attr(node)
        if attr is not None:
            spec = cls.guarded.get(attr)
            if spec is None or in_init:
                return
            lock, writes_only = spec
            if writes_only and not is_write:
                return
            if cls.canon(lock) not in held:
                kind = "write to" if is_write else "read of"
                self.emit(ctx, node.lineno, "CCY001",
                          f"{kind} '{attr}' (guarded-by: {lock}) "
                          f"outside 'with self.{lock}'")
            return
        # cross-object read-modify-write of a uniquely-guarded field
        if (id(node) in rmw and node.attr in self.unique_guarded
                and not isinstance(node.value, ast.Name)):
            owner = self.unique_guarded[node.attr]
            self.emit(ctx, node.lineno, "CCY001",
                      f"read-modify-write of '{node.attr}' (guarded by "
                      f"{owner}) through another object; use a locked "
                      f"method on the owner")

    # -- CCY003 + requires-lock call sites -------------------------------
    def _check_call(self, ctx: FileContext, cls: ClassModel, mname: str,
                    node: ast.Call, held: frozenset,
                    in_init: bool) -> None:
        callee = _is_self_attr(node.func)
        if callee is not None:
            req = cls.requires.get(callee)
            if req is not None and not in_init:
                if cls.canon(req) not in held:
                    self.emit(ctx, node.lineno, "CCY001",
                              f"call to '{callee}()' (requires-lock: "
                              f"{req}) without holding self.{req}")
            if held:
                for lk in self._acquired(cls, callee):
                    for h in held:
                        self.edges.append(_Edge(
                            (cls.name, h), (cls.name, lk), ctx.rel,
                            node.lineno,
                            cls.lock_kinds.get(lk, "Lock")))
        if held:
            what = self._blocking(cls, node, held)
            if what is not None:
                locks = ", ".join(sorted(held))
                self.emit(ctx, node.lineno, "CCY003",
                          f"blocking call {what} while holding "
                          f"'{locks}'")

    def _blocking(self, cls: ClassModel, node: ast.Call,
                  held: frozenset) -> Optional[str]:
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            return None
        attr = fn.attr
        recv = fn.value
        rname = _receiver_name(recv)
        kwargs = {kw.arg for kw in node.keywords}
        if attr == "sleep" and rname == "time":
            return "time.sleep()"
        if attr == "join":
            if isinstance(recv, (ast.Constant, ast.JoinedStr)):
                return None                # ", ".join(...)
            if rname in ("os", "path", "posixpath", "ntpath"):
                return None
            return f"{rname or '?'}.join()"
        if attr == "put":
            return f"queue {rname or '?'}.put()"
        if attr == "get":
            if ({"timeout", "block"} & kwargs
                    or (rname and _QUEUEISH_RE.search(rname))):
                return f"queue {rname or '?'}.get()"
            return None
        if attr in ("recv", "send") and _looks_like_connector(recv):
            return f"connector {rname}.{attr}()"
        if attr in ("step", "prefix_snapshot", "seed_prefixes"):
            return f"engine {rname or '?'}.{attr}()"
        if attr == "wait":
            lk = cls.lock_of(recv)
            if lk is not None and lk in held:
                return None                # Condition.wait on held lock
            return f"{rname or '?'}.wait()"
        return None

    # -- CCY002: cycles over the acquisition graph -----------------------
    def _cycles(self) -> None:
        ctx_by_rel = {c.rel: c for c in self.corpus.contexts}
        adj: Dict[Tuple[str, str], Set[Tuple[str, str]]] = {}
        for e in self.edges:
            if e.src != e.dst:
                adj.setdefault(e.src, set()).add(e.dst)

        def reaches(a, b, seen) -> bool:
            if a == b:
                return True
            seen.add(a)
            return any(n not in seen and reaches(n, b, seen)
                       for n in adj.get(a, ()))

        reported: Set[Tuple[str, int, str]] = set()
        for e in self.edges:
            ctx = ctx_by_rel.get(e.rel)
            if ctx is None:
                continue
            if e.src == e.dst:
                if e.dst_kind == "Lock":
                    key = (e.rel, e.line, "self")
                    if key not in reported:
                        reported.add(key)
                        self.emit(ctx, e.line, "CCY002",
                                  f"re-acquires non-reentrant lock "
                                  f"'{e.dst[1]}' already held "
                                  f"(self-deadlock in {e.src[0]})")
                continue
            if reaches(e.dst, e.src, set()):
                key = (e.rel, e.line, "cycle")
                if key not in reported:
                    reported.add(key)
                    self.emit(ctx, e.line, "CCY002",
                              f"lock-order cycle: acquires "
                              f"'{e.dst[1]}' while holding "
                              f"'{e.src[1]}' but the reverse order "
                              f"also exists in {e.src[0]}")


def lock_pass(corpus: Corpus) -> _LockPass:
    lp = corpus.cache.get("lock_pass")
    if lp is None:
        lp = _LockPass(corpus)
        lp.run()
        corpus.cache["lock_pass"] = lp
    return lp


class _LockRule(Rule):
    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        lp = lock_pass(corpus)
        return [f for f in lp.findings.get(ctx.rel, [])
                if f.code == self.code]


@register
class LockDiscipline(_LockRule):
    code = "CCY001"
    name = "lock-discipline"
    summary = ("access to a '# guarded-by:' field outside its lock, or a "
               "'# requires-lock:' method called without it")


@register
class LockOrder(_LockRule):
    code = "CCY002"
    name = "lock-order"
    summary = ("cycle in the static lock-acquisition graph, or re-entry "
               "on a non-reentrant threading.Lock")


@register
class BlockingUnderLock(_LockRule):
    code = "CCY003"
    name = "blocking-call-under-lock"
    summary = ("queue put/get, join, sleep, connector recv/send, or "
               "engine step while holding a lock")
