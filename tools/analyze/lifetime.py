"""RES001 — connector key lifetime.

Every connector ``send()`` / ``recv()`` key flow must reach a
``release()`` / ``read_and_release()`` in the same function, or
demonstrably hand ownership off:

  - the key variable is captured by a nested ``def`` / ``lambda``
    (deferred cleanup callbacks, the orchestrator's resolve path),
  - the key expression is passed to another call (an owner that manages
    the lifetime),
  - the send/recv result is kept (a tracked ``TransferHandle``),
  - the key is returned.

``recv()`` inside ``with pytest.raises(...)`` is exempt — the test is
asserting the transfer fails, so there is nothing to release.

Receivers are matched with the same heuristic the DEP rules use: a name
containing ``conn`` (``conn``, ``connector``, ``seed_connector``).
Keys are compared structurally (``ast.dump``), so f-string keys like
``f"k{i}"`` pair up between ``send`` and ``release``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.analyze.framework import (Corpus, FileContext, Finding, Rule,
                                     register)
from tools.analyze.locks import _looks_like_connector

_OPENERS = {"send", "recv"}
_CLOSERS = {"release", "read_and_release"}


def _scopes(tree: ast.Module) -> Iterator[Tuple[str, List[ast.stmt]]]:
    """Yield (name, body) for the module and every (nested) function."""
    yield "<module>", tree.body
    stack: List[ast.AST] = [tree]
    while stack:
        node = stack.pop()
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield sub.name, sub.body


def _walk_scope(stmts: List[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements, yielding nested function nodes but not
    descending into their bodies (they are separate scopes)."""
    todo: List[ast.AST] = list(stmts)
    while todo:
        node = todo.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        todo.extend(ast.iter_child_nodes(node))


def _key_of(call: ast.Call) -> Optional[ast.expr]:
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    return None


def _is_raises_with(node: ast.AST) -> bool:
    if not isinstance(node, (ast.With, ast.AsyncWith)):
        return False
    for item in node.items:
        e = item.context_expr
        if isinstance(e, ast.Call):
            fn = e.func
            name = (fn.attr if isinstance(fn, ast.Attribute)
                    else fn.id if isinstance(fn, ast.Name) else None)
            if name == "raises":
                return True
    return False


@register
class ConnectorLifetime(Rule):
    code = "RES001"
    name = "connector-key-lifetime"
    summary = ("connector send()/recv() key never reaches release()/"
               "read_and_release() and does not escape the function")

    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        out: List[Finding] = []
        tree = ctx.tree
        if tree is None:
            return out
        for scope_name, body in _scopes(tree):
            out.extend(self._check_scope(ctx, scope_name, body))
        return out

    def _check_scope(self, ctx: FileContext, scope_name: str,
                     body: List[ast.stmt]) -> List[Finding]:
        opened: Dict[str, Tuple[int, Set[str]]] = {}   # key dump
        closed: Set[str] = set()
        escaped: Set[str] = set()
        raises_keys: Set[str] = set()
        nested: List[ast.AST] = []
        returned_names: Set[str] = set()
        kept_results: Set[int] = set()     # Call ids whose result is kept

        for node in _walk_scope(body):
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                if isinstance(node.value, ast.Call):
                    kept_results.add(id(node.value))
            elif isinstance(node, ast.Return) and node.value is not None:
                if isinstance(node.value, ast.Call):
                    kept_results.add(id(node.value))
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name):
                        returned_names.add(sub.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                nested.append(node)
            if _is_raises_with(node):
                for sub in _walk_scope(node.body):
                    call = self._channel_op(sub)
                    if call is not None and call[0] in _OPENERS:
                        key = _key_of(call[2])
                        if key is not None:
                            raises_keys.add(ast.dump(key))

        for node in _walk_scope(body):
            op = self._channel_op(node)
            if op is None:
                # key passed to a non-connector call: ownership handed
                # off to something that may manage the lifetime
                if (isinstance(node, ast.Call)
                        and not (isinstance(node.func, ast.Attribute)
                                 and _looks_like_connector(
                                     node.func.value))):
                    for arg in list(node.args) + [kw.value
                                                  for kw in node.keywords]:
                        escaped.add(ast.dump(arg))
                continue
            kind, recv_name, call = op
            key = _key_of(call)
            if key is None:
                continue
            dump = ast.dump(key)
            if kind in _CLOSERS:
                closed.add(dump)
            else:
                if id(call) in kept_results:
                    escaped.add(dump)      # tracked TransferHandle
                if dump not in opened:
                    opened[dump] = (call.lineno, set())
                opened[dump][1].add(f"{recv_name}.{kind}")

        captured: Set[str] = set()
        for fn in nested:
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Name):
                    captured.add(sub.id)

        out: List[Finding] = []
        for dump, (lineno, ops) in sorted(opened.items(),
                                          key=lambda kv: kv[1][0]):
            if dump in closed or dump in escaped or dump in raises_keys:
                continue
            # a Name key captured by a nested def/lambda escapes
            if dump.startswith("Name("):
                name = dump.split("'")[1]
                if name in captured or name in returned_names:
                    continue
            out.append(ctx.finding(
                lineno, self.code,
                f"connector key from {'/'.join(sorted(ops))} never "
                f"released in '{scope_name}' (add release()/"
                f"read_and_release() or hand the key to an owner)"))
        return out

    @staticmethod
    def _channel_op(node: ast.AST
                    ) -> Optional[Tuple[str, str, ast.Call]]:
        if not isinstance(node, ast.Call):
            return None
        fn = node.func
        if not isinstance(fn, ast.Attribute):
            return None
        if fn.attr not in _OPENERS | _CLOSERS:
            return None
        if not _looks_like_connector(fn.value):
            return None
        rname = (fn.value.id if isinstance(fn.value, ast.Name)
                 else fn.value.attr)
        return fn.attr, rname, node
