"""DEP001 / DEP002 — deprecated surfaces, migrated from tools/lint.py.

Kept in lockstep with the runtime DeprecationWarnings (see
``src/repro/connector/base.py`` and ``src/repro/core/orchestrator.py``)
so the static gate and the warnings retire together.  Suppression is
code-aware here — ``# noqa: DEP001`` no longer silences every other
rule on the line the way the old bare-substring match did.
"""
from __future__ import annotations

import ast
from typing import List

from tools.analyze.framework import (Corpus, FileContext, Finding, Rule,
                                     register)
from tools.analyze.locks import _looks_like_connector

_DEP_CONNECTOR_TRIO = {"put", "get", "delete"}
_DEP_ORCH_KWARGS = {"queue_capacity", "recv_timeout", "replicas", "routing",
                    "engine_factories", "engine_specs", "isolation",
                    "warm_seed"}          # bare backend= predates the bag


@register
class ConnectorTrio(Rule):
    code = "DEP001"
    name = "deprecated-connector-trio"
    summary = ("connector put()/get()/delete() is deprecated; use the "
               "channel API send()/recv()/release()")

    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        out: List[Finding] = []
        if ctx.tree is None:
            return out
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in _DEP_CONNECTOR_TRIO
                    and _looks_like_connector(fn.value)):
                out.append(ctx.finding(
                    node.lineno, self.code,
                    f"connector .{fn.attr}() is deprecated; use the "
                    f"channel API (send()/recv()/release())"))
        return out


@register
class OrchestratorKwargs(Rule):
    code = "DEP002"
    name = "deprecated-orchestrator-kwargs"
    summary = ("Orchestrator(replicas=..., routing=..., ...) kwargs bag "
               "is deprecated; pass config=ServeConfig(...)")

    def check(self, ctx: FileContext, corpus: Corpus) -> List[Finding]:
        out: List[Finding] = []
        if ctx.tree is None:
            return out
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if (isinstance(node.func, ast.Name)
                    and node.func.id == "Orchestrator"):
                for kw in node.keywords:
                    if kw.arg in _DEP_ORCH_KWARGS:
                        out.append(ctx.finding(
                            kw.value.lineno, self.code,
                            f"Orchestrator kwargs bag ({kw.arg}=...) is "
                            f"deprecated; pass config=ServeConfig(...)"))
        return out
