"""Adaptive sharding rules: logical-dim -> mesh-axis PartitionSpecs.

Rules (DESIGN.md §5):
  - parameters: tensor-parallel over "model" (heads / ffn / experts / vocab),
    replicated over "data" and "pod";
  - batch dims shard over ("pod","data") when divisible;
  - decode KV caches shard kv-heads over "model" when divisible by the
    model-axis size, else the sequence axis (context parallelism); with
    batch=1 (long_500k) the sequence axis also takes the data axis.
GSPMD pads non-divisible sharded dims, so annotations never change
semantics — only layout.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ----------------------------------------------------------------------------
# parameter specs, by param-tree path
# ----------------------------------------------------------------------------

_PARAM_RULES = {
    # name-suffix -> spec WITHOUT the stacked-layer leading dim
    "embed": P("model", None),
    "lm_head": P(None, "model"),
    "wq": P(None, "model", None),      # (d, nq, hd)
    "wk": P(None, "model", None),
    "wv": P(None, "model", None),
    "wo": P("model", None, None),      # (nq, hd, d)
    "bq": P("model", None),
    "bk": P("model", None),
    "bv": P("model", None),
    "wg": P(None, "model"),            # (d, f)
    "wu": P(None, "model"),
    "wd": P("model", None),            # (f, d)
    "router": P(None, "model"),        # (d, E)
    "in_proj": P(None, "model"),       # (d, 2di[+...])
    "conv_w": P(None, "model"),        # (cw, ch)
    "conv_b": P("model"),
    "x_proj": P("model", None),        # (di, r+2n)
    "dt_proj": P(None, "model"),       # (r, di)
    "dt_bias": P("model"),
    "A_log": P("model"),               # (di, n) or (nh,) -- padded below
    "D": P("model"),
    "out_proj": P("model", None),      # (di, d)
    "scale": P(None),                  # rmsnorm
    # DiT extras
    "xwq": P(None, "model", None), "xwk": P(None, "model", None),
    "xwv": P(None, "model", None), "xwo": P("model", None, None),
    "ada": P(None, "model"), "in_projd": P(None, "model"),
    "t_mlp1": P(None, "model"), "t_mlp2": P("model", None),
}

# MoE expert-stacked weights get the expert dim sharded instead
_MOE_RULES = {
    "wg": P("model", None, None),      # (E, d, f)
    "wu": P("model", None, None),
    "wd": P("model", None, None),      # (E, f, d)
}


def _key_name(k) -> str:
    return k.key if hasattr(k, "key") else str(k)


def fit_spec(mesh: Mesh, shape: Tuple[int, ...], spec: P) -> P:
    """Make a spec legal for explicit in_shardings: every named axis must
    evenly divide its dim. Axes that don't fit are dropped; if "model" gets
    dropped entirely, it is re-placed on the largest dim it divides (so
    params stay tensor-parallel even when the preferred dim is too small,
    e.g. 8 kv heads on a model=16 axis -> shard head_dim instead)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    parts = parts[:len(shape)]
    dropped = []
    for i, (dim, p) in enumerate(zip(shape, parts)):
        if p is None:
            continue
        if dim % axis_size(mesh, p) != 0:
            dropped.append(p)
            parts[i] = None
    for p in dropped:
        if p in parts:
            continue
        cands = [i for i, (dim, q) in enumerate(zip(shape, parts))
                 if q is None and dim % axis_size(mesh, p) == 0 and dim > 1]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            parts[best] = p
    return P(*parts)


def param_specs(cfg: ModelConfig, params, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching ``params`` (handles stacked-layer dims)."""

    def spec_for(path, leaf):
        names = [_key_name(k) for k in path]
        last = names[-1]
        in_moe = "moe" in names
        rules = _MOE_RULES if (in_moe and last in _MOE_RULES) else _PARAM_RULES
        base = rules.get(last)
        if base is None:
            return P()
        # stacked-layer leading dims: params under "blocks"/"mamba" carry an
        # extra (L,) axis relative to the single-layer shapes.
        extra = leaf.ndim - len(base)
        if extra < 0:  # e.g. A_log (nh,) vs rule (di,n): trim
            base = P(*base[:leaf.ndim])
            extra = leaf.ndim - len(base)
        spec = P(*([None] * extra), *base)
        if mesh is not None:
            spec = fit_spec(mesh, leaf.shape, spec)
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# activation / cache specs
# ----------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Best batch sharding: the largest prefix of ("pod","data") dividing B."""
    axes = data_axes(mesh)
    while axes and batch % axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    return axes or None


def token_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    b = batch_spec(mesh, batch)
    if cfg.modality == "audio_frames":
        return P(b, None, None)
    return P(b, None)


def kv_cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                   seq_shard_axes=None) -> dict:
    """Specs for the decode cache dict of init_decode_cache."""
    msize = mesh.shape["model"]
    b = batch_spec(mesh, batch)
    specs = {}
    if "k" in _cache_keys(cfg):
        if cfg.num_kv_heads % msize == 0:
            kvspec = P(None, b, seq_shard_axes, "model", None)
        else:
            # context parallelism: shard the sequence axis over "model"
            kvspec = P(None, b, ("model",) if seq_shard_axes is None
                       else seq_shard_axes, None, None)
        if b is None and batch == 1:
            # batch=1 long-context: sequence takes the data axes too
            prev = kvspec[2]
            prev_axes = ((prev,) if isinstance(prev, str)
                         else tuple(prev or ()))
            kvspec = P(None, None, ("data",) + prev_axes, *kvspec[3:])
        specs["k"] = kvspec
        specs["v"] = kvspec
        # int8 KV quantization scales: same layout minus the head_dim axis
        sc = P(*tuple(kvspec)[:-1])
        specs["k_scale"] = sc
        specs["v_scale"] = sc
    if cfg.arch_type in ("ssm", "hybrid"):
        if cfg.ssm_version == 1:
            specs["ssm_h"] = P(None, b, "model", None)       # (L,B,di,n)
        else:
            specs["ssm_h"] = P(None, b, "model", None, None)  # (L,B,nh,hp,n)
        specs["ssm_conv"] = P(None, b, None, "model")        # (L,B,cw-1,ch)
    return specs


def _cache_keys(cfg: ModelConfig):
    keys = []
    if cfg.arch_type in ("dense", "moe", "vlm", "audio", "hybrid"):
        keys += ["k", "v"]
    if cfg.arch_type in ("ssm", "hybrid"):
        keys += ["ssm_h", "ssm_conv"]
    return keys
