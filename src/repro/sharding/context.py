"""Distribution context: lets model code (e.g. the MoE layer) pick a
distribution-aware implementation when lowering for a mesh, without
threading mesh handles through every forward signature.

The dry-run / production launchers set this; CPU engines leave it unset.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class DistContext:
    mesh: object
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    moe_impl: str = "gspmd"          # "gspmd" | "ep" (shard_map expert-par)


_CTX: Optional[DistContext] = None


def set_context(ctx: Optional[DistContext]) -> None:
    global _CTX
    _CTX = ctx


def get_context() -> Optional[DistContext]:
    return _CTX


@contextmanager
def distribution(ctx: DistContext):
    prev = get_context()
    set_context(ctx)
    try:
        yield
    finally:
        set_context(prev)
