"""Per-stage execution workers (paper §3.1: fully disaggregated stages).

A :class:`StageWorker` owns exactly one stage engine and runs it in a
dedicated thread, so every stage of an any-to-any pipeline batches and
steps independently — a slow DiT stage no longer stalls the AR decoder in
front of it.  The worker's interface to the rest of the system is two
queues:

  - **inbox** — bounded queue of :class:`StageInput` items.  Bounded puts
    are the per-edge backpressure mechanism: when a consumer stage falls
    behind, the router blocks on (and accounts for) the full inbox instead
    of buffering unboundedly.
  - **emit** — callback onto the router's event queue; every StageEvent
    the engine produces is forwarded there.

Inputs can carry either resolved model inputs or a lazy ``resolve``
closure (connector ``recv`` + edge transfer), so payload deserialization
runs in the *destination* stage's thread, overlapping transfers with other
stages' compute.

Lifecycle: ``start`` → (``submit`` | engine steps)* → ``stop(drain=...)``
→ ``join``.  ``stop(drain=True)`` lets the worker finish everything
already admitted or queued; ``drain=False`` exits after the current step.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.request import Request, StageEvent


@dataclass
class StageInput:
    """One unit of admission into a stage engine."""
    request: Request
    sampling: Any                                   # SamplingParams
    inputs: Optional[Dict[str, Any]] = None         # resolved inputs, or
    resolve: Optional[Callable[[], Optional[dict]]] = None  # lazy recv+transfer
    origin: str = "admission"                       # edge id or "admission"
    # run if the item is discarded unadmitted (e.g. non-draining shutdown):
    # releases the connector entry the resolve closure would have consumed
    cleanup: Optional[Callable[[], None]] = None
    t_submit: float = field(default_factory=time.perf_counter)


class WorkerMetrics:
    """Per-stage serving metrics; survives worker restarts (the
    orchestrator passes the same object into each generation of worker)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.queue_delays: List[float] = []
        self.admitted = 0
        self.filtered = 0
        self.finished = 0
        self.events = 0
        self.steps = 0
        self.errors = 0
        self.max_inbox_depth = 0
        self.first_active: Optional[float] = None
        self.last_active: Optional[float] = None

    def note_admit(self, delay: float) -> None:
        with self._lock:
            self.queue_delays.append(delay)
            self.admitted += 1

    def note_active(self) -> None:
        now = time.perf_counter()
        with self._lock:
            if self.first_active is None:
                self.first_active = now
            self.last_active = now

    def note_depth(self, depth: int) -> None:
        with self._lock:
            self.max_inbox_depth = max(self.max_inbox_depth, depth)

    def snapshot(self, busy_time: float = 0.0) -> Dict[str, float]:
        with self._lock:
            qd = np.asarray(self.queue_delays, np.float64)
            span = ((self.last_active - self.first_active)
                    if self.first_active is not None else 0.0)
            return {
                "admitted": self.admitted,
                "filtered": self.filtered,
                "finished": self.finished,
                "events": self.events,
                "steps": self.steps,
                "errors": self.errors,
                "max_inbox_depth": self.max_inbox_depth,
                "queue_delay_mean": float(qd.mean()) if qd.size else 0.0,
                "queue_delay_p50": (float(np.percentile(qd, 50))
                                    if qd.size else 0.0),
                "queue_delay_p95": (float(np.percentile(qd, 95))
                                    if qd.size else 0.0),
                "busy_time": busy_time,
                "active_span": span,
                "busy_frac": (busy_time / span) if span > 0 else 0.0,
                "finished_per_s": (self.finished / span) if span > 0 else 0.0,
            }


class StageWorker:
    """Runs one StageEngine in its own thread with an inbox/emit loop."""

    _IDLE_WAIT = 0.02            # idle block on the inbox (stop() wakes it)

    def __init__(self, name: str, engine: Any,
                 emit: Callable[[str, StageEvent], None], *,
                 capacity: int = 64,
                 metrics: Optional[WorkerMetrics] = None) -> None:
        self.name = name
        self.engine = engine
        self.emit = emit
        self.inbox: "queue.Queue[Optional[StageInput]]" = queue.Queue(
            maxsize=capacity)
        self.metrics = metrics or WorkerMetrics()
        self.error: Optional[str] = None            # fatal engine failure
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._stepping = False
        self._thread = threading.Thread(target=self._loop,
                                        name=f"stage-{name}", daemon=True)
        self._started = False

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if not self._started:
            self._started = True
            self._thread.start()

    def stop(self, drain: bool = True) -> None:
        self._drain_on_stop = drain
        self._stop.set()
        try:                                 # wake an idle-blocked loop
            self.inbox.put_nowait(None)
        except queue.Full:
            pass

    def join(self, timeout: Optional[float] = None) -> None:
        if self._started:
            self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    @property
    def active(self) -> bool:
        """True while the worker is admitting or stepping (quiescence)."""
        return self._stepping

    # -- producer side -----------------------------------------------------
    def submit(self, item: StageInput,
               timeout: Optional[float] = None) -> bool:
        """Bounded put → per-edge backpressure. Blocks until space (or
        ``timeout``); returns False if the worker stopped or timed out."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while True:
            try:
                self.inbox.put(item, timeout=0.05)
                self.metrics.note_depth(self.inbox.qsize())
                return True
            except queue.Full:
                # a stopped or crashed worker will never drain its inbox —
                # report unavailable instead of blocking the router forever
                if self._stop.is_set() or self.error is not None or (
                        self._started and not self._thread.is_alive()):
                    return False
                if deadline is not None and time.perf_counter() > deadline:
                    return False

    # -- worker thread -----------------------------------------------------
    def _admit(self, item: StageInput) -> None:
        req = item.request
        delay = time.perf_counter() - item.t_submit
        self.metrics.note_admit(delay)
        req.note_queue_delay(self.name, delay)
        try:
            inputs = item.inputs
            if item.resolve is not None:
                inputs = item.resolve()
            if inputs is None:               # transfer fn filtered this event
                self.metrics.filtered += 1
                return
            req.mark_stage_start(self.name)
            self.engine.enqueue(req.req_id, inputs, item.sampling, req.data)
        except Exception as e:               # noqa: BLE001 — fault isolation
            self.metrics.errors += 1
            self.emit(self.name, StageEvent(
                req.req_id, "error",
                {"error": f"{item.origin}: {type(e).__name__}: {e}"},
                stage=self.name))

    def _loop(self) -> None:
        eng = self.engine
        while True:
            drained = 0
            while True:                      # drain the inbox
                try:
                    if drained == 0 and not eng.has_work:
                        item = self.inbox.get(timeout=self._IDLE_WAIT)
                    else:
                        item = self.inbox.get_nowait()
                except queue.Empty:
                    break
                drained += 1
                if item is not None:
                    self._stepping = True
                    self.metrics.note_active()
                    self._admit(item)
                    self._stepping = False
            if self._stop.is_set():
                if (not self._drain_on_stop
                        or (self.inbox.empty() and not eng.has_work)):
                    break
            if not eng.has_work:
                continue
            self._stepping = True
            self.metrics.note_active()
            try:
                events = eng.step()
            except Exception as e:           # noqa: BLE001 — engine died
                self.error = f"{type(e).__name__}: {e}"
                self._stepping = False
                break
            self.metrics.steps += 1
            for ev in events:
                ev.stage = ev.stage or self.name
                self.metrics.events += 1
                # one request-finish per request: the last streamed chunk,
                # or a "finished" event that wasn't preceded by chunks (an
                # AR stage that streamed emits BOTH — count it once)
                streamed = (isinstance(ev.payload, dict)
                            and ev.payload.get("n_chunks", 0) > 0)
                if (ev.kind == "finished" and not streamed) or (
                        ev.kind == "chunk" and ev.is_last):
                    self.metrics.finished += 1
                self.emit(self.name, ev)
            self.metrics.note_active()
            self._stepping = False
        self._discard_inbox()

    def _discard_inbox(self) -> None:
        """On a non-draining (or aborted) exit, run queued items' cleanups
        so connector entries they would have consumed are released."""
        while True:
            try:
                item = self.inbox.get_nowait()
            except queue.Empty:
                return
            if item is not None and item.cleanup is not None:
                try:
                    item.cleanup()
                except Exception:            # noqa: BLE001 — best effort
                    pass
