"""Per-stage execution workers (paper §3.1: fully disaggregated stages).

A :class:`StageWorker` owns exactly one stage engine and runs it in a
dedicated thread, so every stage of an any-to-any pipeline batches and
steps independently — a slow DiT stage no longer stalls the AR decoder in
front of it.  The worker's interface to the rest of the system is two
queues:

  - **inbox** — bounded queue of :class:`StageInput` items.  Bounded puts
    are the per-edge backpressure mechanism: when a consumer stage falls
    behind, the router blocks on (and accounts for) the full inbox instead
    of buffering unboundedly.
  - **emit** — callback onto the router's event queue; every StageEvent
    the engine produces is forwarded there.

Inputs can carry either resolved model inputs or a lazy ``resolve``
closure (connector ``recv`` + edge transfer), so payload deserialization
runs in the *destination* stage's thread, overlapping transfers with other
stages' compute.

Lifecycle: ``start`` → (``submit`` | engine steps)* → ``stop(drain=...)``
→ ``join``.  ``stop(drain=True)`` lets the worker finish everything
already admitted or queued; ``drain=False`` exits after the current step.

Multi-replica stages (paper §3.2, flexible GPU allocation): a
:class:`ReplicaSet` puts N independently-stepping engine replicas behind
one ``submit`` — a pluggable routing policy picks the replica, and
``scale_up`` / ``scale_down(drain=True)`` grow or shrink the set at
runtime without dropping in-flight requests.  The router only ever sees
the set's queues, so multi-replica serving is invisible to the graph.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.request import Request, StageEvent


@dataclass
class StageInput:
    """One unit of admission into a stage engine."""
    request: Request
    sampling: Any                                   # SamplingParams
    inputs: Optional[Dict[str, Any]] = None         # resolved inputs, or
    resolve: Optional[Callable[[], Optional[dict]]] = None  # lazy recv+transfer
    origin: str = "admission"                       # edge id or "admission"
    # run if the item is discarded unadmitted (e.g. non-draining shutdown):
    # releases the connector entry the resolve closure would have consumed
    cleanup: Optional[Callable[[], None]] = None
    # block-hash chain for cache-affinity routing; None = not yet probed
    affinity_hints: Optional[Any] = None
    # per-request monotonic sequence number, stamped at the connector
    # boundary on streamed chunks (None = unordered item).  The destination
    # worker asserts strictly-increasing delivery per request; the replica
    # set routes all seq-carrying items of one request to one replica.
    seq: Optional[int] = None
    seq_last: bool = False              # final chunk: tracker entry drops
    t_submit: float = field(default_factory=time.perf_counter)


class WorkerMetrics:
    """Per-stage serving metrics; survives worker restarts (the
    orchestrator passes the same object into each generation of worker)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.queue_delays: List[float] = []   # guarded-by: _lock
        self.admitted = 0                     # guarded-by: _lock
        self.filtered = 0                     # guarded-by: _lock
        self.finished = 0                     # guarded-by: _lock
        self.events = 0                       # guarded-by: _lock
        self.steps = 0                        # guarded-by: _lock
        self.errors = 0                       # guarded-by: _lock
        # out-of-order streamed chunks seen
        self.order_violations = 0             # guarded-by: _lock
        # process replicas died/killed/wedged
        self.replica_failures = 0             # guarded-by: _lock
        self.max_inbox_depth = 0              # guarded-by: _lock
        self.first_active: Optional[float] = None    # guarded-by: _lock
        self.last_active: Optional[float] = None     # guarded-by: _lock
        # busy seconds banked from engines this replica no longer runs
        # (scale_down drops the engine object, its dwell must survive)
        self.retired_busy = 0.0               # guarded-by: _lock

    def note_admit(self, delay: float) -> None:
        with self._lock:
            self.queue_delays.append(delay)
            self.admitted += 1

    def note_active(self) -> None:
        now = time.perf_counter()
        with self._lock:
            if self.first_active is None:
                self.first_active = now
            self.last_active = now

    def note_depth(self, depth: int) -> None:
        with self._lock:
            self.max_inbox_depth = max(self.max_inbox_depth, depth)

    def note_retired_busy(self, busy_time: float) -> None:
        with self._lock:
            self.retired_busy += busy_time

    def note_replica_failure(self) -> None:
        with self._lock:
            self.replica_failures += 1

    def note_filtered(self) -> None:
        with self._lock:
            self.filtered += 1

    def note_error(self) -> None:
        with self._lock:
            self.errors += 1

    def note_order_violation(self) -> None:
        with self._lock:
            self.order_violations += 1
            self.errors += 1

    def note_steps(self, n: int = 1) -> None:
        if n:
            with self._lock:
                self.steps += n

    def note_event(self, ev: StageEvent) -> None:
        """Count one emitted event.  One request-finish per request: the
        last streamed chunk, or a "finished" event that wasn't preceded
        by chunks (an AR stage that streamed emits BOTH — count once)."""
        streamed = (isinstance(ev.payload, dict)
                    and ev.payload.get("n_chunks", 0) > 0)
        finish = (ev.kind == "finished" and not streamed) or (
            ev.kind == "chunk" and ev.is_last)
        with self._lock:
            self.events += 1
            if finish:
                self.finished += 1

    def raw_delays(self) -> List[float]:
        """Copy of the raw queue-delay samples (merged percentiles across
        replicas, windowed deltas in the scaling controller)."""
        with self._lock:
            return list(self.queue_delays)

    def snapshot(self, busy_time: float = 0.0) -> Dict[str, float]:
        with self._lock:
            busy_time = busy_time + self.retired_busy
            qd = np.asarray(self.queue_delays, np.float64)
            span = ((self.last_active - self.first_active)
                    if self.first_active is not None else 0.0)
            return {
                "admitted": self.admitted,
                "filtered": self.filtered,
                "finished": self.finished,
                "events": self.events,
                "steps": self.steps,
                "errors": self.errors,
                "order_violations": self.order_violations,
                "replica_failures": self.replica_failures,
                "max_inbox_depth": self.max_inbox_depth,
                "queue_delay_mean": float(qd.mean()) if qd.size else 0.0,
                "queue_delay_p50": (float(np.percentile(qd, 50))
                                    if qd.size else 0.0),
                "queue_delay_p95": (float(np.percentile(qd, 95))
                                    if qd.size else 0.0),
                "busy_time": busy_time,
                "active_span": span,
                "busy_frac": (busy_time / span) if span > 0 else 0.0,
                "finished_per_s": (self.finished / span) if span > 0 else 0.0,
            }


class StageWorker:
    """Runs one StageEngine in its own thread with an inbox/emit loop."""

    isolation = "thread"
    _IDLE_WAIT = 0.02            # idle block on the inbox (stop() wakes it)

    def __init__(self, name: str, engine: Any,
                 emit: Callable[[str, StageEvent], None], *,
                 capacity: int = 64,
                 metrics: Optional[WorkerMetrics] = None,
                 label: Optional[str] = None) -> None:
        self.name = name                 # stage name (routing + metrics)
        self.label = label or name       # thread label (replica-qualified)
        self.engine = engine
        self.emit = emit
        self.inbox: "queue.Queue[Optional[StageInput]]" = queue.Queue(
            maxsize=capacity)
        self.metrics = metrics or WorkerMetrics()
        self.error: Optional[str] = None            # fatal engine failure
        self._last_seq: Dict[int, int] = {}         # req_id -> last chunk seq
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._stepping = False
        self._thread = threading.Thread(target=self._loop,
                                        name=f"stage-{self.label}",
                                        daemon=True)
        self._started = False

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if not self._started:
            self._started = True
            self._thread.start()

    def stop(self, drain: bool = True) -> None:
        self._drain_on_stop = drain
        self._stop.set()
        try:                                 # wake an idle-blocked loop
            self.inbox.put_nowait(None)
        except queue.Full:
            pass

    def join(self, timeout: Optional[float] = None) -> None:
        if self._started:
            self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    @property
    def active(self) -> bool:
        """True while the worker is admitting or stepping (quiescence)."""
        return self._stepping

    def load(self) -> int:
        """Live load proxy for routing: queued + admitted-but-unfinished
        work plus one if mid-step.  Advisory (read cross-thread)."""
        return (self.inbox.qsize() + getattr(self.engine, "queue_depth", 0)
                + (1 if self._stepping else 0))

    # -- producer side -----------------------------------------------------
    def submit(self, item: StageInput,
               timeout: Optional[float] = None) -> bool:
        """Bounded put → per-edge backpressure. Blocks until space (or
        ``timeout``); returns False if the worker stopped or timed out."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while True:
            try:
                self.inbox.put(item, timeout=0.05)
                self.metrics.note_depth(self.inbox.qsize())
                return True
            except queue.Full:
                # a stopped or crashed worker will never drain its inbox —
                # report unavailable instead of blocking the router forever
                if self._stop.is_set() or self.error is not None or (
                        self._started and not self._thread.is_alive()):
                    return False
                if deadline is not None and time.perf_counter() > deadline:
                    return False

    # -- worker thread -----------------------------------------------------
    def _admit(self, item: StageInput) -> None:
        req = item.request
        delay = time.perf_counter() - item.t_submit
        self.metrics.note_admit(delay)
        req.note_queue_delay(self.name, delay)
        if item.seq is not None:
            # per-request FIFO assertion: streamed chunks must arrive in
            # the order the connector stamped them.  Strictly-increasing
            # (not +1) so a replica handoff mid-stream stays legal while
            # reorders and duplicates within one worker are caught.
            last = self._last_seq.get(req.req_id)
            if last is not None and item.seq <= last:
                self.metrics.note_order_violation()
                self.emit(self.name, StageEvent(
                    req.req_id, "error",
                    {"error": f"{item.origin}: out-of-order chunk "
                              f"seq={item.seq} after {last}"},
                    stage=self.name))
                return
            if item.seq_last:
                self._last_seq.pop(req.req_id, None)
            else:
                self._last_seq[req.req_id] = item.seq
        try:
            inputs = item.inputs
            if item.resolve is not None:
                inputs = item.resolve()
            if inputs is None:               # transfer fn filtered this event
                self.metrics.note_filtered()
                return
            req.mark_stage_start(self.name)
            self.engine.enqueue(req.req_id, inputs, item.sampling, req.data)
        except Exception as e:               # noqa: BLE001 — fault isolation
            self.metrics.note_error()
            self.emit(self.name, StageEvent(
                req.req_id, "error",
                {"error": f"{item.origin}: {type(e).__name__}: {e}"},
                stage=self.name))

    def _loop(self) -> None:
        eng = self.engine
        while True:
            drained = 0
            while True:                      # drain the inbox
                try:
                    if drained == 0 and not eng.has_work:
                        item = self.inbox.get(timeout=self._IDLE_WAIT)
                    else:
                        item = self.inbox.get_nowait()
                except queue.Empty:
                    break
                drained += 1
                if item is not None:
                    self._stepping = True
                    self.metrics.note_active()
                    self._admit(item)
                    self._stepping = False
            if self._stop.is_set():
                if (not self._drain_on_stop
                        or (self.inbox.empty() and not eng.has_work)):
                    break
            if not eng.has_work:
                continue
            self._stepping = True
            self.metrics.note_active()
            try:
                events = eng.step()
            except Exception as e:           # noqa: BLE001 — engine died
                self.error = f"{type(e).__name__}: {e}"
                self._stepping = False
                break
            self.metrics.note_steps()
            for ev in events:
                ev.stage = ev.stage or self.name
                self.metrics.note_event(ev)
                self.emit(self.name, ev)
            self.metrics.note_active()
            self._stepping = False
        self._discard_inbox()

    def _discard_inbox(self) -> None:
        """On a non-draining (or aborted) exit, run queued items' cleanups
        so connector entries they would have consumed are released."""
        while True:
            try:
                item = self.inbox.get_nowait()
            except queue.Empty:
                return
            if item is not None and item.cleanup is not None:
                try:
                    item.cleanup()
                except Exception:            # noqa: BLE001 — best effort
                    pass


class ReplicaSet:
    """N :class:`StageWorker` replicas behind one logical stage.

    Each replica owns a private engine (its own scheduler, KV pool and
    thread); the set's ``submit`` picks a replica through a routing policy
    (``select(stage, [(rid, worker), ...], item) -> rid``) and forwards
    the bounded put, so per-edge backpressure semantics are unchanged.

    ``scale_up`` adds a replica (a given engine, or one from the stage's
    engine factory) and ``scale_down(drain=True)`` retires the least
    loaded replica without losing requests: the victim is removed from
    the routing set first, in-flight submits targeting it are allowed to
    land, and only then is its worker stopped with ``drain=True`` — it
    finishes everything queued plus everything its engine already admitted
    before the thread exits.

    Replica ids are small integers; a retired id is reused by the next
    ``scale_up`` so the per-replica metrics bank stays bounded by the
    maximum concurrent replica count (and keeps accumulating across
    worker generations, like single-replica restarts always have).
    """

    def __init__(self, stage: str, engines: List[Any],
                 emit: Callable[[str, StageEvent], None], *,
                 capacity: int = 64,
                 metrics_bank: Optional[Dict[int, WorkerMetrics]] = None,
                 policy: Any = None,
                 engine_factory: Optional[Callable[[], Any]] = None,
                 warm_seed: bool = True,
                 isolation: str = "thread",
                 engine_spec: Optional[Any] = None,
                 seed_connector: Optional[Any] = None,
                 n_replicas: Optional[int] = None,
                 process_opts: Optional[Dict[str, Any]] = None) -> None:
        if isolation not in ("thread", "process"):
            raise ValueError(f"unknown isolation {isolation!r}")
        if isolation == "process" and engine_spec is None:
            raise ValueError(
                f"stage {stage!r}: isolation='process' needs an "
                f"engine_spec (picklable 'module:callable' recipe)")
        if not engines and isolation != "process":
            raise ValueError(f"stage {stage!r} needs at least one engine")
        self.stage = stage
        self.emit = emit
        self.capacity = capacity
        self.policy = policy
        self.engine_factory = engine_factory
        self.warm_seed = warm_seed
        self.isolation = isolation
        self.engine_spec = engine_spec
        #: connector carrying warm-seed snapshots (channel API); None
        #: falls back to the direct engine-to-engine hand-off
        self.seed_connector = seed_connector
        self.process_opts = dict(process_opts or {})
        #: audit trail of warm scale-ups:
        #: {"rid", "donor_pages", "pages", "via"}
        self.seed_events: List[Dict[str, Any]] = []      # guarded-by: _lock
        #: audit trail of replica deaths:
        #: {"rid", "reason", "readmitted"}
        self.failure_events: List[Dict[str, Any]] = []   # guarded-by: _lock
        self.metrics_bank = metrics_bank if metrics_bank is not None else {}
        self._lock = threading.Lock()
        self._replicas: Dict[int, Any] = {}  # guarded-by: _lock
        self._order: List[int] = []          # guarded-by: _lock (routable)
        # in-flight submit() puts
        self._pending: Dict[int, int] = {}   # guarded-by: _lock
        # seq-carrying (streamed-chunk) items stick to one replica per
        # request — splitting a chunk stream across replicas would admit
        # it out of order at two engines at once
        self._sticky: Dict[int, int] = {}    # guarded-by: _lock
        self._rr = 0                         # guarded-by: _lock (rr cursor)
        self._seed_seq = 0                   # guarded-by: _lock (seed keys)
        self._started = False                # guarded-by: _lock
        if isolation == "process":
            for rid in range(n_replicas or max(1, len(engines))):
                self._install(rid, None)
        else:
            for rid, eng in enumerate(engines):
                self._install(rid, eng)

    def _install(self, rid: int, engine: Any,
                 routable: bool = True) -> Any:  # requires-lock: _lock
        metrics = self.metrics_bank.setdefault(rid, WorkerMetrics())
        label = f"{self.stage}#{rid}"
        if self.isolation == "process":
            from repro.core.proc_worker import ProcessStageWorker
            w: Any = ProcessStageWorker(
                self.stage, self.engine_spec, self.emit,
                capacity=self.capacity, metrics=metrics, label=label,
                on_failure=self._on_replica_failure, **self.process_opts)
        else:
            w = StageWorker(self.stage, engine, self.emit,
                            capacity=self.capacity, metrics=metrics,
                            label=label)
        self._replicas[rid] = w
        if routable:
            self._order.append(rid)
        return w

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        with self._lock:
            self._started = True
            workers = list(self._replicas.values())
        for w in workers:
            w.start()

    def stop(self, drain: bool = True) -> None:
        with self._lock:
            workers = list(self._replicas.values())
        for w in workers:
            w.stop(drain=drain)

    def join(self, timeout: Optional[float] = None) -> None:
        with self._lock:
            workers = list(self._replicas.values())
        for w in workers:
            w.join(timeout)

    # -- introspection -----------------------------------------------------
    @property
    def n_replicas(self) -> int:
        with self._lock:
            return len(self._order)

    @property
    def replica_ids(self) -> List[int]:
        with self._lock:
            return list(self._order)

    @property
    def engines(self) -> List[Any]:
        with self._lock:
            return [self._replicas[r].engine for r in self._order]

    def workers(self) -> List[Tuple[int, StageWorker]]:
        with self._lock:
            return [(r, self._replicas[r]) for r in self._order]

    @property
    def alive(self) -> bool:
        with self._lock:
            return any(w.alive for w in self._replicas.values())

    @property
    def active(self) -> bool:
        with self._lock:
            return any(w.active for w in self._replicas.values())

    def inbox_empty(self) -> bool:
        with self._lock:
            return all(w.inbox.empty() for w in self._replicas.values())

    @property
    def error(self) -> Optional[str]:
        with self._lock:
            return next((w.error for w in self._replicas.values()
                         if w.error), None)

    def queue_depth(self) -> int:
        """Total live load across replicas (inboxes + engines)."""
        with self._lock:
            return sum(w.load() for w in self._replicas.values())

    # -- producer side -----------------------------------------------------
    def submit(self, item: StageInput,
               timeout: Optional[float] = None) -> bool:
        """Route one item to a replica (policy-chosen) and forward the
        bounded put.  The pending counter pins the chosen replica against
        a concurrent ``scale_down`` until the put lands."""
        with self._lock:
            if not self._order:
                return False
            cands = [(r, self._replicas[r]) for r in self._order]
            sticky = (self._sticky.get(item.request.req_id)
                      if item.seq is not None else None)
            if sticky is not None and sticky in self._order:
                rid = sticky                       # keep the chunk stream
            elif self.policy is not None and len(cands) > 1:
                rid = self.policy.select(self.stage, cands, item)
                if rid not in self._replicas:      # policy bug: fall back
                    rid = cands[0][0]
            elif len(cands) > 1:
                rid = cands[self._rr % len(cands)][0]
                self._rr += 1
            else:
                rid = cands[0][0]
            if item.seq is not None:
                # pin the rest of this request's chunk stream here —
                # FIFO only holds within one replica's inbox
                self._sticky[item.request.req_id] = rid
            self._pending[rid] = self._pending.get(rid, 0) + 1
            w = self._replicas[rid]
        try:
            return w.submit(item, timeout=timeout)
        finally:
            with self._lock:
                self._pending[rid] -= 1

    def forget(self, req_id: int) -> None:
        """Drop a finished/failed request's sticky chunk-stream pin."""
        with self._lock:
            self._sticky.pop(req_id, None)

    # -- replica failure (process isolation) -------------------------------
    def _on_replica_failure(self, worker: Any,
                            items: List[StageInput]) -> None:
        """A process replica died or wedged (detected by its pump thread,
        which calls here): retire it from the routing set and re-admit its
        in-flight items to the survivors.  Requests that no survivor can
        take fail cleanly instead of hanging."""
        with self._lock:
            rid = next((r for r, w in self._replicas.items()
                        if w is worker), None)
            if rid is not None:
                if rid in self._order:
                    self._order.remove(rid)
                del self._replicas[rid]
                for req_id in [k for k, v in self._sticky.items()
                               if v == rid]:
                    del self._sticky[req_id]
                self.failure_events.append({
                    "rid": rid,
                    "reason": getattr(worker, "failure_reason", None),
                    "readmitted": len(items)})
            survivors = bool(self._order)
        if rid is not None:
            # bank the dead engine's last-reported dwell, like scale_down
            self.metrics_bank[rid].note_retired_busy(
                getattr(worker.engine, "busy_time", 0.0))
        for item in items:
            ok = survivors and self.submit(item, timeout=5.0)
            if not ok:
                self.emit(self.stage, StageEvent(
                    item.request.req_id, "error",
                    {"error": f"{self.stage}: replica failed and no "
                              f"survivor accepted the request"},
                    stage=self.stage))

    # -- dynamic scaling ---------------------------------------------------
    def _warm_seed(self, engine: Any) -> Optional[Dict[str, Any]]:
        """Seed a new engine's prefix index from the warmest sibling.

        With a ``seed_connector`` the snapshot travels through the
        connector channel API: the donor's snapshot is ``send``-published
        under a warm-seed key and the receiver ``recv``s it (a process
        receiver takes the zero-extra-copy manifest route when the
        connector can export one).  Advisory either way: any failure
        (engines without snapshot support, pool too small, transfer
        timeout, mid-extract eviction) degrades to a cold start.  The
        donor snapshot pins its pages only for the duration of the
        extract, so the sibling keeps serving."""
        if not (hasattr(engine, "seed_prefixes")
                and hasattr(engine, "prefix_hint")):
            return None
        with self._lock:
            siblings = [self._replicas[r].engine for r in self._order]
        donor = None
        best = 0
        for eng in siblings:
            pages = getattr(eng, "cached_prefix_pages", 0)
            if pages > best and hasattr(eng, "prefix_snapshot"):
                donor, best = eng, pages
        if donor is None:
            return None
        try:
            snap = donor.prefix_snapshot()
            if not snap:
                return None
            if self.seed_connector is not None:
                seeded, via = self._seed_via_connector(engine, snap)
            else:
                seeded, via = engine.seed_prefixes(snap), "direct"
        except Exception:                        # advisory: cold start
            return None
        if not seeded:
            return None
        return {"donor_pages": best, "pages": seeded, "via": via}

    def _seed_via_connector(self, engine: Any,
                            snap: Any) -> Tuple[int, str]:
        """Route one warm-seed snapshot through the connector channel
        API (send on the donor side, recv/manifest on the receiver)."""
        conn = self.seed_connector
        with self._lock:
            self._seed_seq += 1
            key = f"warmseed/{self.stage}/{self._seed_seq}"
        conn.send(key, {"paths": snap})
        try:
            seed_rpc = getattr(engine, "seed_prefixes", None)
            manifest_of = getattr(conn, "manifest", None)
            owner = getattr(engine, "_w", None)  # RemoteEngineProxy
            if owner is not None and manifest_of is not None and getattr(
                    conn, "cross_process", False):
                # process receiver + cross-process connector: ship the
                # picklable manifest, payload stays in shared memory
                n = owner.seed_manifest(manifest_of(key))
                return int(n or 0), "manifest"
            payload = conn.recv(key, timeout=30.0)
            return int(seed_rpc(payload["paths"])), "connector"
        finally:
            conn.release(key)

    def scale_up(self, engine: Any = None) -> Optional[int]:
        """Add one replica (given engine, a fresh one from the stage
        factory, or — process isolation — a spawned worker built from the
        stage's engine spec); returns its replica id, or None without a
        source.  With ``warm_seed`` the new engine's prefix cache is
        seeded from the sibling holding the most indexed pages before it
        joins the routing set, so its first requests already score
        affinity hits."""
        if self.isolation == "process":
            return self._scale_up_process()
        if engine is None:
            if self.engine_factory is None:
                return None
            engine = self.engine_factory()       # may be slow: outside lock
        seed = self._warm_seed(engine) if self.warm_seed else None
        with self._lock:
            rid = next(i for i in range(len(self._replicas) + 1)
                       if i not in self._replicas)
            w = self._install(rid, engine)
            started = self._started
            if seed is not None:
                self.seed_events.append({"rid": rid, **seed})
        if started:
            w.start()
        return rid

    def _scale_up_process(self) -> Optional[int]:
        """Spawned replicas join in two steps: install unrouted + start
        (the child needs to be live before the warm-seed RPC), then seed,
        then make routable."""
        with self._lock:
            rid = next(i for i in range(len(self._replicas) + 1)
                       if i not in self._replicas)
            w = self._install(rid, None, routable=False)
            started = self._started
        seed = None
        if started:
            w.start()
            if w.wait_ready(timeout=180.0) and self.warm_seed:
                seed = self._warm_seed(w.engine)
        with self._lock:
            self._order.append(rid)
            if seed is not None:
                self.seed_events.append({"rid": rid, **seed})
        return rid

    def scale_down(self, drain: bool = True) -> Optional[int]:
        """Retire the least-loaded replica; never below one.  With
        ``drain=True`` (the default) the victim finishes its queued and
        admitted work before its thread exits — no request is dropped.
        Returns the retired replica id, or None if the set is at minimum.
        Blocks until the victim has drained; call from a control thread
        (the scaling controller), not from the router."""
        with self._lock:
            if len(self._order) <= 1:
                return None
            rid = min(self._order,
                      key=lambda r: (self._replicas[r].load(), r))
            self._order.remove(rid)              # unroutable from now on
            # grab the worker under the lock: a concurrent
            # _on_replica_failure may delete the entry at any moment
            w = self._replicas[rid]
        while True:                              # let in-flight puts land
            with self._lock:
                if self._pending.get(rid, 0) == 0:
                    break
            time.sleep(0.001)
        w.stop(drain=drain)
        w.join(timeout=60.0)
        # bank the retired engine's dwell so stage busy_time survives
        self.metrics_bank[rid].note_retired_busy(
            getattr(w.engine, "busy_time", 0.0))
        with self._lock:
            # pop, not del: the failure path may have removed it already
            self._replicas.pop(rid, None)
            # unpin chunk streams that stuck to the retired replica
            for req_id in [k for k, v in self._sticky.items() if v == rid]:
                del self._sticky[req_id]
        return rid
