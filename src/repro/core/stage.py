"""Stage abstraction (paper §3.2, Figure 3(b)).

A *stage* is one model component of an any-to-any pipeline (an AR LLM, a
DiT, an encoder, or a custom module), declared with:

  - ``kind``: which execution engine serves it ("ar" | "diffusion" |
    "encode" | "custom");
  - ``preprocess``: per-iteration hook that can inject data produced by
    preceding stages into the stage's model inputs (e.g. the Talker
    concatenating Thinker hidden states at every decode step);
  - ``resources``: engine knobs (max batch, KV pages, mesh axes / submesh)
    — the user-facing runtime configuration of Figure 3(c);
  - engine-specific model handles (config + params + step functions are
    owned by the engine, keeping the stage declaration model-agnostic).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    runtime_checkable)

from repro.core.request import StageEvent

# preprocess(request_data: dict, model_inputs: dict) -> dict
PreprocessFn = Callable[[Dict[str, Any], Dict[str, Any]], Dict[str, Any]]
# transfer(request_data: dict, payload: Any) -> dict  (downstream inputs)
TransferFn = Callable[[Dict[str, Any], Any], Dict[str, Any]]


@runtime_checkable
class StageEngine(Protocol):
    """What a stage execution engine must provide to be served.

    The contract the disaggregated backend relies on:

      - ``enqueue`` and ``step`` are only ever called from ONE thread (the
        stage's worker thread, or the main thread on the lock-step compat
        path) — engines need no internal locking;
      - ``step`` executes at most one iteration of work (one scheduler
        plan, one denoising batch, ...) and returns the StageEvents it
        produced: finished outputs, streamed chunks;
      - ``has_work`` is cheap and may be read from other threads for
        quiescence detection (it is advisory there — the worker's own
        thread re-checks before sleeping).
    """

    name: str

    def enqueue(self, req_id: int, inputs: Dict[str, Any], sampling: Any,
                data: Dict[str, Any]) -> None: ...

    def step(self) -> List[StageEvent]: ...

    @property
    def has_work(self) -> bool: ...

    @property
    def queue_depth(self) -> int: ...


@dataclass
class StageSpec:
    name: str
    kind: str                                   # ar | diffusion | encode | custom
    model: Any = None                           # engine-specific model bundle
    preprocess: Optional[PreprocessFn] = None
    resources: Dict[str, Any] = field(default_factory=dict)
    is_output: bool = False                     # terminal stage: emits request output

    def __post_init__(self):
        assert self.kind in ("ar", "diffusion", "encode", "custom"), self.kind


@dataclass
class StageEdge:
    src: str
    dst: str
    transfer: TransferFn
    streaming: bool = False                     # forward chunks before src finishes
    connector: str = "inline"                   # inline | shm | mooncake
