"""Stage graph (paper §3.2): nodes are stages, edges are transfer functions.

The graph is a DAG; sources (in-degree 0) receive the request's initial
inputs, ``is_output`` stages contribute to the request's final outputs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.stage import StageEdge, StageSpec


class StageGraph:
    def __init__(self) -> None:
        self.stages: Dict[str, StageSpec] = {}
        self.edges: List[StageEdge] = []

    def add_stage(self, spec: StageSpec) -> "StageGraph":
        if spec.name in self.stages:
            raise ValueError(f"duplicate stage {spec.name!r}")
        self.stages[spec.name] = spec
        return self

    def add_edge(self, src: str, dst: str, transfer, *, streaming: bool = False,
                 connector: str = "inline") -> "StageGraph":
        for s in (src, dst):
            if s not in self.stages:
                raise ValueError(f"unknown stage {s!r}")
        self.edges.append(StageEdge(src, dst, transfer, streaming=streaming,
                                    connector=connector))
        return self

    # ---- topology ------------------------------------------------------

    def out_edges(self, name: str) -> List[StageEdge]:
        return [e for e in self.edges if e.src == name]

    def in_edges(self, name: str) -> List[StageEdge]:
        return [e for e in self.edges if e.dst == name]

    def in_degree(self, name: str) -> int:
        return sum(1 for e in self.edges if e.dst == name)

    @staticmethod
    def edge_id(edge: StageEdge) -> str:
        """Canonical edge name used for connector keys and metrics."""
        return f"{edge.src}->{edge.dst}"

    def sources(self) -> List[str]:
        return [n for n in self.stages if self.in_degree(n) == 0]

    def output_stages(self) -> List[str]:
        outs = [n for n, s in self.stages.items() if s.is_output]
        if outs:
            return outs
        # default: sinks
        return [n for n in self.stages if not self.out_edges(n)]

    def topo_order(self) -> List[str]:
        indeg = {n: self.in_degree(n) for n in self.stages}
        order, frontier = [], [n for n, d in indeg.items() if d == 0]
        while frontier:
            n = frontier.pop()
            order.append(n)
            for e in self.out_edges(n):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    frontier.append(e.dst)
        if len(order) != len(self.stages):
            raise ValueError("stage graph has a cycle")
        return order

    def validate(self) -> None:
        self.topo_order()
        if not self.sources():
            raise ValueError("stage graph has no source stage")
