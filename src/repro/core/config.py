"""Typed serving configuration (the ``ServeConfig`` API).

One frozen dataclass replaces the kwargs bag that used to sprawl across
``Orchestrator.__init__`` (``backend``, ``queue_capacity``,
``recv_timeout``, ``replicas``, ``routing``, ``engine_factories``,
``warm_seed``, ``isolation``) and the launcher's flag soup:

  - :class:`ServeConfig` — backend-wide knobs plus a per-stage mapping of
    :class:`StageConfig`; validated eagerly in ``__post_init__`` so a bad
    spec fails at construction, not mid-serve.
  - :class:`StageConfig` — replicas, routing override, thread/process
    isolation, prefix-cache override, and the stage's engine sources: an
    in-process ``engine_factory`` closure and/or a picklable
    :class:`EngineSpec` that a spawned process replica rebuilds from.
  - :class:`EngineSpec` — ``"module:callable"`` + kwargs, the only form
    of engine construction that can cross a spawn boundary (closures
    over initialized params cannot be pickled; deterministic builders
    rebuild identical params from the same seed).

``ServeConfig.from_args`` is the one place argparse flags become a
config; ``ServeConfig.from_kwargs`` backs the deprecated Orchestrator
kwargs shim for one release.

This module is import-light (no jax) so spawned worker children can load
it cheaply.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from types import MappingProxyType
from typing import Any, Callable, Dict, Mapping, Optional

BACKENDS = ("threaded", "sync")
ISOLATIONS = ("thread", "process")
ROUTING_NAMES = ("round_robin", "least_loaded", "affinity")


def _valid_routing(routing: Any) -> bool:
    """A routing value is a known policy name or a policy-like object."""
    if isinstance(routing, str):
        return routing in ROUTING_NAMES
    return hasattr(routing, "select")


@dataclass(frozen=True)
class EngineSpec:
    """Picklable recipe for building a stage engine in another process.

    ``target`` is ``"pkg.module:callable"``; the callable is invoked with
    ``kwargs`` and must return a ready engine.  Builders must be
    deterministic (same kwargs → same params) so a process replica is
    byte-equivalent to the in-process engine built from the same spec.
    """
    target: str
    kwargs: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if ":" not in self.target:
            raise ValueError(
                f"EngineSpec target must be 'module:callable', "
                f"got {self.target!r}")
        object.__setattr__(self, "kwargs",
                           MappingProxyType(dict(self.kwargs)))

    def build(self) -> Any:
        mod_name, _, fn_name = self.target.partition(":")
        fn = getattr(importlib.import_module(mod_name), fn_name)
        return fn(**self.kwargs)

    def __reduce__(self):
        # MappingProxyType does not pickle; rebuild from a plain dict
        return (EngineSpec, (self.target, dict(self.kwargs)))


@dataclass(frozen=True)
class StageConfig:
    """Per-stage serving spec inside a :class:`ServeConfig`."""
    replicas: int = 1
    routing: Optional[Any] = None        # None = inherit ServeConfig.routing
    isolation: str = "thread"
    prefix_cache: Optional[bool] = None  # None = pipeline default
    engine_factory: Optional[Callable[[], Any]] = None
    engine_spec: Optional[EngineSpec] = None

    def __post_init__(self) -> None:
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.isolation not in ISOLATIONS:
            raise ValueError(f"isolation must be one of {ISOLATIONS}, "
                             f"got {self.isolation!r}")
        if self.routing is not None and not _valid_routing(self.routing):
            raise ValueError(f"unknown routing {self.routing!r} "
                             f"(have {ROUTING_NAMES})")
        if self.isolation == "process" and self.engine_spec is None:
            raise ValueError(
                "isolation='process' needs an engine_spec — a process "
                "replica rebuilds its engine from a picklable "
                "EngineSpec('module:callable', kwargs), not from an "
                "in-process factory closure")


@dataclass(frozen=True)
class ServeConfig:
    """Validated, immutable serving configuration."""
    backend: str = "threaded"
    queue_capacity: int = 64
    recv_timeout: float = 60.0
    routing: Any = "affinity"
    warm_seed: bool = True
    stages: Mapping[str, StageConfig] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1, "
                             f"got {self.queue_capacity}")
        if self.recv_timeout <= 0:
            raise ValueError("recv_timeout must be > 0, "
                             f"got {self.recv_timeout}")
        if not _valid_routing(self.routing):
            raise ValueError(f"unknown routing {self.routing!r} "
                             f"(have {ROUTING_NAMES})")
        stages = {}
        for name, sc in dict(self.stages).items():
            if not isinstance(sc, StageConfig):
                raise TypeError(f"stages[{name!r}] must be a StageConfig, "
                                f"got {type(sc).__name__}")
            stages[name] = sc
        object.__setattr__(self, "stages", MappingProxyType(stages))
        if self.backend == "sync":
            for name, sc in stages.items():
                if sc.replicas > 1:
                    raise ValueError(
                        f"sync (lock-step) backend is single-replica; "
                        f"stage {name!r} asks for {sc.replicas}")
                if sc.isolation != "thread":
                    raise ValueError(
                        f"sync backend cannot isolate stage {name!r} "
                        f"in a process")

    # -- accessors ---------------------------------------------------------
    def stage(self, name: str) -> StageConfig:
        """Per-stage config, defaulted for stages not explicitly listed."""
        return self.stages.get(name, StageConfig())

    def stage_routing(self, name: str) -> Any:
        sc = self.stage(name)
        return sc.routing if sc.routing is not None else self.routing

    def with_stage(self, name: str, **changes: Any) -> "ServeConfig":
        """A copy with one stage's config replaced/updated."""
        stages = dict(self.stages)
        stages[name] = replace(stages.get(name, StageConfig()), **changes)
        return replace(self, stages=stages)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_kwargs(cls, *, backend: str = "threaded",
                    queue_capacity: int = 64, recv_timeout: float = 60.0,
                    replicas: Optional[Dict[str, int]] = None,
                    routing: Any = "affinity",
                    engine_factories: Optional[Dict[str, Any]] = None,
                    engine_specs: Optional[Dict[str, EngineSpec]] = None,
                    isolation: Any = "thread",
                    warm_seed: bool = True) -> "ServeConfig":
        """Build from the legacy Orchestrator kwargs bag.  ``isolation``
        is either one mode for every stage or a per-stage dict."""
        stages: Dict[str, StageConfig] = {}
        names = set(replicas or ()) | set(engine_factories or ()) \
            | set(engine_specs or ())
        if isinstance(isolation, dict):
            names |= set(isolation)
        for name in sorted(names):
            iso = (isolation.get(name, "thread")
                   if isinstance(isolation, dict) else isolation)
            stages[name] = StageConfig(
                replicas=(replicas or {}).get(name, 1),
                isolation=iso,
                engine_factory=(engine_factories or {}).get(name),
                engine_spec=(engine_specs or {}).get(name))
        return cls(backend=backend, queue_capacity=queue_capacity,
                   recv_timeout=recv_timeout, routing=routing,
                   warm_seed=warm_seed, stages=stages)

    @classmethod
    def from_args(cls, args: Any,
                  engine_factories: Optional[Dict[str, Any]] = None,
                  engine_specs: Optional[Dict[str, EngineSpec]] = None
                  ) -> "ServeConfig":
        """The one argparse → config funnel (``launch/serve.py``).

        Consumes ``--backend``, ``--replicas STAGE=N[,..]``, ``--routing``,
        ``--isolation STAGE=MODE[,..]`` (or a bare MODE for every stage),
        ``--queue-capacity``, ``--recv-timeout`` and ``--no-warm-seed``
        from the parsed namespace; missing attributes fall back to the
        dataclass defaults so partial namespaces (tests) work.
        """
        replicas = _parse_stage_map(getattr(args, "replicas", None), int,
                                    "replicas")
        iso_arg = getattr(args, "isolation", None)
        if iso_arg and "=" not in iso_arg:
            isolation: Any = iso_arg                  # one mode for all
        else:
            isolation = _parse_stage_map(iso_arg, str, "isolation") or {}
        return cls.from_kwargs(
            backend=getattr(args, "backend", "threaded"),
            queue_capacity=getattr(args, "queue_capacity", 64),
            recv_timeout=getattr(args, "recv_timeout", 60.0),
            replicas=replicas,
            routing=getattr(args, "routing", "affinity"),
            engine_factories=engine_factories,
            engine_specs=engine_specs,
            isolation=isolation,
            warm_seed=getattr(args, "warm_seed", True))


def _parse_stage_map(text: Optional[str], cast: Callable[[str], Any],
                     what: str) -> Optional[Dict[str, Any]]:
    """Parse ``STAGE=V[,STAGE=V...]`` flag syntax into a dict."""
    if not text:
        return None
    out: Dict[str, Any] = {}
    for part in text.split(","):
        stage, _, v = part.partition("=")
        if not v:
            raise ValueError(f"--{what}: expected STAGE=VALUE, got {part!r}")
        out[stage.strip()] = cast(v.strip())
    return out
