"""Orchestrator (paper §3.1/§3.3): routes requests through the stage graph.

One process manages all stage engines: each tick it steps every engine,
collects finished / streamed outputs, applies edge transfer functions,
moves payloads through the per-edge connector (put/get with metadata
control plane), and enqueues downstream stage inputs. Streaming edges
forward chunks before the upstream stage finishes, overlapping stages
(paper's "streaming stage output").
"""
from __future__ import annotations

import time
from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.connector.base import Connector
from repro.connector.mooncake import make_connector
from repro.core.graph import StageGraph
from repro.core.request import Request, StageEvent
from repro.engine.sampling import SamplingParams


class Orchestrator:
    def __init__(self, graph: StageGraph, engines: Dict[str, Any],
                 connectors: Optional[Dict[str, Connector]] = None):
        graph.validate()
        self.graph = graph
        self.engines = engines
        for name in graph.stages:
            if name not in engines:
                raise ValueError(f"no engine bound for stage {name!r}")
        # one connector instance per backend kind (shared across edges)
        kinds = {e.connector for e in graph.edges}
        self.connectors = connectors or {k: make_connector(k) for k in kinds}
        self.requests: Dict[int, Request] = {}
        self._outputs_pending: Dict[int, set] = {}
        self.completed: List[Request] = []
        self._transfer_log: List[dict] = []

    # ------------------------------------------------------------------
    def submit(self, request: Request) -> None:
        self.requests[request.req_id] = request
        self._outputs_pending[request.req_id] = set(
            self.graph.output_stages())
        for src in self.graph.sources():
            spec = self.graph.stages[src]
            request.mark_stage_start(src)
            self.engines[src].enqueue(
                request.req_id, request.inputs,
                SamplingParams(**request.sampling) if request.sampling
                else SamplingParams(),
                request.data)

    # ------------------------------------------------------------------
    def _route(self, ev: StageEvent) -> None:
        req = self.requests[ev.req_id]
        stage = ev.stage
        if ev.kind == "finished":
            req.mark_stage_end(stage)
        for edge in self.graph.out_edges(stage):
            if ev.kind == "chunk" and not edge.streaming:
                continue                      # non-streaming edges wait
            if ev.kind == "finished" and edge.streaming and ev.payload.get(
                    "n_chunks", 0) > 0:
                continue                      # chunks already forwarded
            conn = self.connectors[edge.connector]
            key = f"{edge.src}->{edge.dst}/{req.req_id}/{ev.chunk_index}"
            conn.put(key, ev.payload)
            payload = conn.get(key)
            conn.delete(key)
            self._transfer_log.append({
                "edge": f"{edge.src}->{edge.dst}",
                "connector": edge.connector,
                "req_id": req.req_id,
            })
            try:
                inputs = edge.transfer(req.data, payload)
            except Exception as e:
                # a broken user transfer fn fails THIS request, not the
                # serving loop: mark failed + complete so callers unblock
                req.failed = (f"transfer {edge.src}->{edge.dst}: "
                              f"{type(e).__name__}: {e}")
                req.completion_time = time.perf_counter()
                self._outputs_pending.pop(req.req_id, None)
                self.completed.append(req)
                continue
            if inputs is None:
                continue                      # transfer fn filtered this event
            if ev.kind == "chunk":
                inputs.setdefault("chunk_index", ev.chunk_index)
                inputs.setdefault("is_last_chunk", ev.is_last)
            dst = self.graph.stages[edge.dst]
            req.mark_stage_start(edge.dst)
            self.engines[edge.dst].enqueue(
                req.req_id, inputs,
                SamplingParams(**req.sampling) if req.sampling
                else SamplingParams(),
                req.data)

        # terminal output collection
        spec = self.graph.stages[stage]
        outs = self._outputs_pending.get(ev.req_id)
        if outs is None or stage not in outs:
            return
        if req.first_output_time is None:
            req.first_output_time = time.perf_counter()
        if ev.kind == "finished" or (ev.kind == "chunk" and ev.is_last):
            req.outputs.setdefault(stage, []).append(ev.payload)
            req.mark_stage_end(stage)
            outs.discard(stage)
            if not outs:
                req.completion_time = time.perf_counter()
                self.completed.append(req)
        elif ev.kind == "chunk":
            req.outputs.setdefault(stage, []).append(ev.payload)

    # ------------------------------------------------------------------
    def tick(self) -> int:
        """Step every engine once; returns number of events processed."""
        n = 0
        for name in self.graph.topo_order():
            for ev in self.engines[name].step():
                ev.stage = ev.stage or name
                self._route(ev)
                n += 1
        return n

    def run(self, max_ticks: int = 100_000) -> List[Request]:
        for _ in range(max_ticks):
            if all(r.completion_time is not None
                   for r in self.requests.values()):
                break
            busy = any(self.engines[n].has_work for n in self.graph.stages)
            self.tick()
            if not busy:
                break
        return self.completed

    # ------------------------------------------------------------------
    def stage_busy_times(self) -> Dict[str, float]:
        return {n: getattr(self.engines[n], "busy_time", 0.0)
                for n in self.graph.stages}

    def connector_stats(self) -> Dict[str, Any]:
        return {k: c.stats for k, c in self.connectors.items()}
