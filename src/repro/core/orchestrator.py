"""Orchestrator (paper §3.1/§3.3): event-driven router over per-stage
workers — the fully disaggregated execution backend.

Two backends share all routing logic:

  - ``threaded`` (default): every stage engine runs in its own
    :class:`~repro.core.worker.StageWorker` thread with a bounded inbox;
    a router thread consumes the shared event queue that all workers emit
    into, applies edge transfer functions through the connector channel
    API (``send`` on the upstream side, lazy ``recv`` inside the
    destination worker), and pushes downstream stage inputs.  Stages
    batch and step concurrently and independently — a slow stage fills
    its own inbox (per-edge backpressure) instead of stalling the whole
    pipeline.  Online arrivals enter through ``submit`` at any time.

  - ``sync``: the original lock-step loop — each ``tick`` steps every
    engine once in topo order and routes synchronously.  Kept as the
    ablation baseline (bench_online measures threaded vs sync) and for
    tests that single-step engines by hand.

``run()`` is the compatibility path: submit-all → drain → return
completed.  It works identically on both backends, so offline callers
never see the threads.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional

from repro.connector.base import Connector
from repro.connector.mooncake import make_connector
from repro.core.graph import StageGraph
from repro.core.request import Request, StageEvent
from repro.core.worker import StageInput, StageWorker, WorkerMetrics
from repro.engine.sampling import SamplingParams


class Orchestrator:
    def __init__(self, graph: StageGraph, engines: Dict[str, Any],
                 connectors: Optional[Dict[str, Connector]] = None, *,
                 backend: str = "threaded", queue_capacity: int = 64,
                 recv_timeout: float = 60.0):
        graph.validate()
        if backend not in ("threaded", "sync"):
            raise ValueError(f"unknown backend {backend!r}")
        self.graph = graph
        self.engines = engines
        for name in graph.stages:
            if name not in engines:
                raise ValueError(f"no engine bound for stage {name!r}")
        # one connector instance per backend kind (shared across edges)
        kinds = {e.connector for e in graph.edges}
        self.connectors = connectors or {k: make_connector(k) for k in kinds}
        self.backend = backend
        self.queue_capacity = queue_capacity
        self.recv_timeout = recv_timeout
        self.requests: Dict[int, Request] = {}
        self._outputs_pending: Dict[int, set] = {}
        self.completed: List[Request] = []
        #: stream of finished Requests, in completion order — the online
        #: front-end consumes this while the backend keeps serving
        self.completions: "queue.Queue[Request]" = queue.Queue()
        self._transfer_log: List[dict] = []
        self._lock = threading.RLock()
        # ---- threaded backend state ----
        self._workers: Dict[str, StageWorker] = {}
        self._stage_metrics = {n: WorkerMetrics() for n in graph.stages}
        self.edge_stats = {
            StageGraph.edge_id(e): {"transfers": 0, "backpressure_s": 0.0}
            for e in graph.edges}
        self._events: "queue.Queue[tuple]" = queue.Queue()
        self._unrouted = 0
        self._counter_lock = threading.Lock()
        self._router_thread: Optional[threading.Thread] = None
        self._router_stop = threading.Event()
        self._started = False

    # ------------------------------------------------------------------
    def _sp(self, req: Request) -> SamplingParams:
        return (SamplingParams(**req.sampling) if req.sampling
                else SamplingParams())

    def submit(self, request: Request) -> None:
        """Admit one request: its initial inputs go to every source stage.
        Callable at any time while the threaded backend is serving."""
        with self._lock:
            self.requests[request.req_id] = request
            self._outputs_pending[request.req_id] = set(
                self.graph.output_stages())
        for src in self.graph.sources():
            if self._started:
                ok = self._workers[src].submit(StageInput(
                    request, self._sp(request), inputs=request.inputs))
                if not ok:
                    self._fail(request, f"admission to {src!r} rejected")
            else:
                request.mark_stage_start(src)
                self.engines[src].enqueue(
                    request.req_id, request.inputs, self._sp(request),
                    request.data)

    # ------------------------------------------------------------------
    # threaded backend lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        """Spin up one worker thread per stage plus the router thread."""
        if self.backend != "threaded":
            raise RuntimeError("start() requires backend='threaded'")
        if self._started:
            return
        self._router_stop = threading.Event()
        self._workers = {
            name: StageWorker(name, self.engines[name], self._emit,
                              capacity=self.queue_capacity,
                              metrics=self._stage_metrics[name])
            for name in self.graph.stages}
        self._started = True
        for w in self._workers.values():
            w.start()
        self._router_thread = threading.Thread(
            target=self._router_loop, name="stage-router", daemon=True)
        self._router_thread.start()

    def _emit(self, stage: str, ev: StageEvent) -> None:
        with self._counter_lock:
            self._unrouted += 1
        self._events.put((stage, ev))

    def _router_loop(self) -> None:
        while True:
            try:
                stage, ev = self._events.get(timeout=0.01)
            except queue.Empty:
                if self._router_stop.is_set():
                    break
                continue
            try:
                self._route(ev)
            except Exception as e:  # noqa: BLE001 — isolate to the request
                req = self.requests.get(ev.req_id)
                if req is not None:
                    self._fail(req, f"router: {type(e).__name__}: {e}")
            finally:
                with self._counter_lock:
                    self._unrouted -= 1

    @property
    def worker_error(self) -> Optional[str]:
        """First fatal stage-engine failure, if any — online front-ends
        should poll this instead of waiting out their time limit."""
        return next((w.error for w in self._workers.values() if w.error),
                    None)

    def _quiescent(self) -> bool:
        with self._counter_lock:
            if self._unrouted:
                return False
        if any(w.active or not w.inbox.empty()
               for w in self._workers.values()):
            return False
        return not any(self.engines[n].has_work for n in self.graph.stages)

    def drain(self, timeout: Optional[float] = None,
              poll: float = 0.005) -> bool:
        """Block until every submitted request completed (True) or the
        system quiesces with requests still unfinished / timeout (False)."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        quiet = 0
        while True:
            with self._lock:
                done = all(r.completion_time is not None
                           for r in self.requests.values())
            if done:
                return True
            if self.worker_error:
                raise RuntimeError(
                    f"stage worker died: {self.worker_error}")
            if deadline is not None and time.perf_counter() > deadline:
                return False
            # a request can legitimately never complete (e.g. a transfer fn
            # filtered its only event) — exit once nothing is in flight,
            # like the lock-step loop's "engines idle" exit
            if self._quiescent():
                quiet += 1
                if quiet >= 3:
                    return False
            else:
                quiet = 0
            time.sleep(poll)

    def shutdown(self, drain: bool = True) -> None:
        """Stop workers (upstream-first when draining, so final events
        cascade downstream) and then the router."""
        if not self._started:
            return
        for name in self.graph.topo_order():
            w = self._workers[name]
            w.stop(drain=drain)
            w.join(timeout=30.0)
            while drain:  # flush this stage's last events downstream
                with self._counter_lock:
                    if self._unrouted == 0:
                        break
                time.sleep(0.002)
        self._router_stop.set()
        if self._router_thread is not None:
            self._router_thread.join(timeout=30.0)
        self._started = False

    # ------------------------------------------------------------------
    # routing (runs on the router thread, or on the caller in sync mode)
    # ------------------------------------------------------------------
    def _fail(self, req: Request, msg: str) -> None:
        with self._lock:
            if req.completion_time is not None:
                req.failed = req.failed or msg
                return
            req.failed = msg
            req.completion_time = time.perf_counter()
            self._outputs_pending.pop(req.req_id, None)
            self.completed.append(req)
        self.completions.put(req)

    def _finish(self, req: Request) -> None:
        with self._lock:
            req.completion_time = time.perf_counter()
            self._outputs_pending.pop(req.req_id, None)
            self.completed.append(req)
        self.completions.put(req)

    @staticmethod
    def _apply_transfer(edge, req: Request, payload, kind: str,
                        chunk_index: int, is_last: bool):
        """Edge transfer + chunk metadata defaulting — the ONE place both
        the sync path and the worker-side resolve closure go through."""
        inputs = edge.transfer(req.data, payload)
        if inputs is None:
            return None                       # transfer fn filtered this event
        if kind == "chunk":
            inputs.setdefault("chunk_index", chunk_index)
            inputs.setdefault("is_last_chunk", is_last)
        return inputs

    def _forward(self, edge, req: Request, ev: StageEvent) -> None:
        conn = self.connectors[edge.connector]
        eid = StageGraph.edge_id(edge)
        key = f"{eid}/{req.req_id}/{ev.chunk_index}"
        self._transfer_log.append({
            "edge": eid, "connector": edge.connector, "req_id": req.req_id})
        if self._started:
            # upstream side publishes; the destination worker receives,
            # deserializes and applies the transfer in ITS thread
            conn.send(key, ev.payload)
            kind, chunk_index, is_last = ev.kind, ev.chunk_index, ev.is_last
            recv_timeout = self.recv_timeout

            def resolve(conn=conn, key=key, edge=edge, req=req, kind=kind,
                        chunk_index=chunk_index, is_last=is_last):
                try:
                    payload = conn.recv(key, timeout=recv_timeout)
                finally:
                    conn.release(key)
                return self._apply_transfer(edge, req, payload, kind,
                                            chunk_index, is_last)

            item = StageInput(req, self._sp(req), resolve=resolve,
                              origin=f"transfer {eid}",
                              cleanup=lambda: conn.release(key))
            t0 = time.perf_counter()
            ok = self._workers[edge.dst].submit(item)
            es = self.edge_stats[eid]
            es["transfers"] += 1
            es["backpressure_s"] += time.perf_counter() - t0
            if not ok:
                conn.release(key)             # never delivered: end lifetime
                self._fail(req, f"{eid}: downstream worker unavailable")
            return
        # ---- sync (lock-step) path ----
        conn.put(key, ev.payload)
        payload = conn.get(key)
        conn.delete(key)
        self.edge_stats[eid]["transfers"] += 1
        try:
            inputs = self._apply_transfer(edge, req, payload, ev.kind,
                                          ev.chunk_index, ev.is_last)
        except Exception as e:
            # a broken user transfer fn fails THIS request, not the
            # serving loop: mark failed + complete so callers unblock
            self._fail(req, f"transfer {eid}: {type(e).__name__}: {e}")
            return
        if inputs is None:
            return
        req.mark_stage_start(edge.dst)
        self.engines[edge.dst].enqueue(req.req_id, inputs, self._sp(req),
                                       req.data)

    def _route(self, ev: StageEvent) -> None:
        req = self.requests[ev.req_id]
        stage = ev.stage
        if ev.kind == "error":
            # fault isolation: the failing stage input killed one request
            self._fail(req, str(ev.payload.get("error", "stage error")))
            return
        if ev.kind == "finished":
            req.mark_stage_end(stage)
        for edge in self.graph.out_edges(stage):
            if ev.kind == "chunk" and not edge.streaming:
                continue                      # non-streaming edges wait
            if ev.kind == "finished" and edge.streaming and ev.payload.get(
                    "n_chunks", 0) > 0:
                continue                      # chunks already forwarded
            if req.completion_time is not None and req.failed:
                break                         # request already failed
            self._forward(edge, req, ev)

        # terminal output collection
        outs = self._outputs_pending.get(ev.req_id)
        if outs is None or stage not in outs:
            return
        if req.first_output_time is None:
            req.first_output_time = time.perf_counter()
        if ev.kind == "finished" or (ev.kind == "chunk" and ev.is_last):
            req.outputs.setdefault(stage, []).append(ev.payload)
            req.mark_stage_end(stage)
            outs.discard(stage)
            if not outs:
                self._finish(req)
        elif ev.kind == "chunk":
            req.outputs.setdefault(stage, []).append(ev.payload)

    # ------------------------------------------------------------------
    # lock-step compat path
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """Step every engine once; returns number of events processed.
        Only valid while the threaded backend is NOT running."""
        if self._started:
            raise RuntimeError(
                "tick() is the lock-step path; shutdown() the threaded "
                "backend first")
        n = 0
        for name in self.graph.topo_order():
            for ev in self.engines[name].step():
                ev.stage = ev.stage or name
                self._route(ev)
                n += 1
        return n

    def run(self, max_ticks: int = 100_000,
            timeout: Optional[float] = None) -> List[Request]:
        """Compatibility path: drain everything submitted so far and
        return the completed requests (offline inference)."""
        if self.backend == "sync":
            for _ in range(max_ticks):
                if all(r.completion_time is not None
                       for r in self.requests.values()):
                    break
                busy = any(self.engines[n].has_work
                           for n in self.graph.stages)
                self.tick()
                if not busy:
                    break
            return self.completed
        self.start()
        try:
            self.drain(timeout=timeout)
        finally:
            # always tear the threads down, even when drain() raises on a
            # dead worker — otherwise the backend stays _started forever
            self.shutdown(drain=False)
        return self.completed

    # ------------------------------------------------------------------
    def stage_busy_times(self) -> Dict[str, float]:
        return {n: getattr(self.engines[n], "busy_time", 0.0)
                for n in self.graph.stages}

    def stage_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-stage serving metrics: queueing delay, busy fraction,
        throughput, inbox high-water mark, prefix-cache hit rates."""
        out = {}
        for n in self.graph.stages:
            m = self._stage_metrics[n].snapshot(
                busy_time=getattr(self.engines[n], "busy_time", 0.0))
            ps = getattr(self.engines[n], "prefix_stats", None)
            if ps is not None and ps.get("lookups"):
                total = ps["cached_tokens"] + ps["computed_tokens"]
                m["cached_tokens"] = ps["cached_tokens"]
                m["computed_tokens"] = ps["computed_tokens"]
                m["prefix_hit_rate"] = (ps["cached_tokens"] / total
                                        if total else 0.0)
            out[n] = m
        return out

    def connector_stats(self) -> Dict[str, Any]:
        return {k: c.stats for k, c in self.connectors.items()}
