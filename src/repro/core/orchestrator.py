"""Orchestrator (paper §3.1/§3.3): event-driven router over per-stage
workers — the fully disaggregated execution backend.

Two backends share all routing logic:

  - ``threaded`` (default): every stage engine runs in its own
    :class:`~repro.core.worker.StageWorker` thread with a bounded inbox;
    a router thread consumes the shared event queue that all workers emit
    into, applies edge transfer functions through the connector channel
    API (``send`` on the upstream side, lazy ``recv`` inside the
    destination worker), and pushes downstream stage inputs.  Stages
    batch and step concurrently and independently — a slow stage fills
    its own inbox (per-edge backpressure) instead of stalling the whole
    pipeline.  Online arrivals enter through ``submit`` at any time.

  - ``sync``: the original lock-step loop — each ``tick`` steps every
    engine once in topo order and routes synchronously.  Kept as the
    ablation baseline (bench_online measures threaded vs sync) and for
    tests that single-step engines by hand.

``run()`` is the compatibility path: submit-all → drain → return
completed.  It works identically on both backends, so offline callers
never see the threads.

Multi-replica stages: every stage is served by a
:class:`~repro.core.worker.ReplicaSet` of N independently-stepping engine
replicas.  A pluggable routing policy picks the replica per item:

  - ``round_robin``   — cycle replicas (baseline);
  - ``least_loaded``  — lowest live load (inbox depth + engine queue
    depth + mid-step), never a retired replica (retired replicas leave
    the candidate set before they stop);
  - ``affinity``      — cache-affinity: score each replica by the longest
    block-hash prefix match against its PageAllocator index (the cheap
    ``prefix_hint`` probe), so shared-prefix traffic lands on the replica
    already holding the pages; falls back to least-loaded when no replica
    holds anything (or the stage cannot prefix-cache the item).

``scale_up(stage)`` / ``scale_down(stage)`` move replicas at runtime —
the scaling controller (repro.core.scaling) drives them from WorkerMetrics
snapshots under a global replica budget (paper §3.2, flexible resource
allocation).
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.connector import shm_transport
from repro.connector.base import Connector, TransferTimeout
from repro.connector.mooncake import make_connector
from repro.core.config import ServeConfig
from repro.core.graph import StageGraph
from repro.core.request import Request, StageEvent
from repro.core.worker import ReplicaSet, StageInput, WorkerMetrics
from repro.engine.sampling import SamplingParams


# ----------------------------------------------------------------------------
# routing policies (ReplicaSet.submit calls select() under the set lock;
# keep it cheap and side-effect free beyond per-stage cursors)
# ----------------------------------------------------------------------------

class RoutingPolicy:
    """select(stage, [(rid, worker), ...], item) -> rid.  Candidates are
    exactly the live, routable replicas — a stopping replica is removed
    from the list before its worker stops, so no policy can pick it."""

    name = "base"

    def select(self, stage: str, replicas: List[Tuple[int, Any]],
               item: StageInput) -> int:
        raise NotImplementedError


class RoundRobinPolicy(RoutingPolicy):
    name = "round_robin"

    def __init__(self) -> None:
        self._next: Dict[str, int] = {}

    def select(self, stage, replicas, item):
        i = self._next.get(stage, 0) % len(replicas)
        self._next[stage] = i + 1
        return replicas[i][0]


class LeastLoadedPolicy(RoutingPolicy):
    name = "least_loaded"

    def select(self, stage, replicas, item):
        return min(replicas, key=lambda rw: (rw[1].load(), rw[0]))[0]


class CacheAffinityPolicy(LeastLoadedPolicy):
    """Deterministic given fixed hints: highest prefix_hint wins, ties
    break by load then lowest replica id; hint 0 everywhere (or no hints
    computable) falls back to least-loaded."""

    name = "affinity"

    def select(self, stage, replicas, item):
        hints = item.affinity_hints
        if hints is None and item.inputs is not None:
            probe = getattr(replicas[0][1].engine, "affinity_hints", None)
            hints = probe(item.inputs) if probe is not None else None
            item.affinity_hints = hints if hints is not None else []
        if hints:
            scored = []
            for rid, w in replicas:
                hint = getattr(w.engine, "prefix_hint", None)
                scored.append((hint(hints) if hint is not None else 0,
                               rid, w))
            best = max(s for s, _, _ in scored)
            if best > 0:
                return min((rw for rw in scored if rw[0] == best),
                           key=lambda rw: (rw[2].load(), rw[1]))[1]
        return super().select(stage, replicas, item)


ROUTING_POLICIES = {p.name: p for p in
                    (RoundRobinPolicy, LeastLoadedPolicy,
                     CacheAffinityPolicy)}


def make_routing_policy(name: str) -> RoutingPolicy:
    if name not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {name!r} "
                         f"(have {sorted(ROUTING_POLICIES)})")
    return ROUTING_POLICIES[name]()


_LEGACY_KWARGS = ("backend", "queue_capacity", "recv_timeout", "replicas",
                  "routing", "engine_factories", "engine_specs",
                  "isolation", "warm_seed")


class Orchestrator:
    def __init__(self, graph: StageGraph, engines: Dict[str, Any],
                 connectors: Optional[Dict[str, Connector]] = None, *,
                 config: Optional[ServeConfig] = None, **legacy: Any):
        graph.validate()
        if legacy:
            unknown = set(legacy) - set(_LEGACY_KWARGS)
            if unknown:
                raise TypeError(f"Orchestrator() got unexpected keyword "
                                f"argument(s) {sorted(unknown)}")
            if config is not None:
                raise TypeError(
                    "pass config=ServeConfig(...) OR the legacy kwargs, "
                    "not both")
            if set(legacy) - {"backend"}:
                # plain backend= selection predates the kwargs bag and is
                # not worth a warning; everything else is the bag
                warnings.warn(
                    "the Orchestrator(replicas=..., routing=..., "
                    "engine_factories=..., ...) kwargs bag is deprecated; "
                    "build a repro.core.config.ServeConfig and pass "
                    "config=... — it validates eagerly and carries "
                    "per-stage isolation",
                    DeprecationWarning, stacklevel=2)
            config = ServeConfig.from_kwargs(**legacy)
        if config is None:
            config = ServeConfig()
        self.config = config
        backend = config.backend
        self.graph = graph
        for name in graph.stages:
            if name not in engines:
                raise ValueError(f"no engine bound for stage {name!r}")
        for name, sc in config.stages.items():
            if name not in graph.stages and (
                    sc.replicas != 1 or sc.isolation != "thread"):
                raise ValueError(f"replica spec for unknown stage {name!r}")
        self.engine_factories = {
            name: sc.engine_factory for name, sc in config.stages.items()
            if sc.engine_factory is not None}
        self.engine_specs = {
            name: sc.engine_spec for name, sc in config.stages.items()
            if sc.engine_spec is not None}
        # thread stages bind one engine or a list of engine replicas; the
        # replica spec grows a stage to N via its engine factory.  Process
        # stages keep only the given engine(s) parent-side (compat views)
        # and spawn ``replicas`` child workers from the engine spec.
        self.stage_replicas: Dict[str, List[Any]] = {
            name: (list(e) if isinstance(e, (list, tuple)) else [e])
            for name, e in engines.items() if name in graph.stages}
        self._proc_replicas: Dict[str, int] = {}   # spawn count per stage
        for name in graph.stages:
            sc = config.stage(name)
            if sc.isolation == "process":
                self._proc_replicas[name] = max(
                    sc.replicas, len(self.stage_replicas[name]))
                continue
            while len(self.stage_replicas[name]) < sc.replicas:
                fac = self.engine_factories.get(name)
                if fac is None:
                    raise ValueError(
                        f"stage {name!r}: replicas={sc.replicas} needs an "
                        f"engine factory (got "
                        f"{len(self.stage_replicas[name])} engine(s))")
                self.stage_replicas[name].append(fac())
        if backend == "sync" and any(len(l) > 1
                                     for l in self.stage_replicas.values()):
            raise ValueError("sync (lock-step) backend is single-replica")
        self.routing = (config.routing
                        if isinstance(config.routing, RoutingPolicy)
                        else make_routing_policy(config.routing))
        self.warm_seed = config.warm_seed
        # requests admitted before start() for a process-isolated source
        # stage are deferred (the parent-side engine never steps for a
        # process stage) and flushed through the workers at start()
        self._deferred: List[Tuple[str, Request]] = []  # guarded-by: _lock
        # one connector instance per backend kind (shared across edges)
        kinds = {e.connector for e in graph.edges}
        self.connectors = connectors or {k: make_connector(k) for k in kinds}
        self.backend = backend
        self.queue_capacity = config.queue_capacity
        self.recv_timeout = config.recv_timeout
        self._seed_connector: Optional[Connector] = None
        self.requests: Dict[int, Request] = {}        # guarded-by: _lock
        self._outputs_pending: Dict[int, set] = {}    # guarded-by: _lock
        self.completed: List[Request] = []            # guarded-by: _lock
        #: stream of finished Requests, in completion order — the online
        #: front-end consumes this while the backend keeps serving
        self.completions: "queue.Queue[Request]" = queue.Queue()
        self._transfer_log: List[dict] = []
        self._lock = threading.RLock()
        # ---- threaded backend state ----
        self._workers: Dict[str, ReplicaSet] = {}
        # per-stage bank of per-replica metrics; survives worker restarts
        # AND scale_down/scale_up cycles (replica ids are reused)
        self._stage_metrics: Dict[str, Dict[int, WorkerMetrics]] = {
            n: {} for n in graph.stages}
        self.edge_stats = {
            StageGraph.edge_id(e): {"transfers": 0, "backpressure_s": 0.0}
            for e in graph.edges}
        self._events: "queue.Queue[tuple]" = queue.Queue()
        # per-(edge, request) chunk sequence counters, stamped at the
        # connector boundary; destination workers assert per-request FIFO.
        # Router-thread only — no lock needed.
        self._edge_seq: Dict[Tuple[str, int], int] = {}
        self._unrouted = 0                   # guarded-by: _counter_lock
        self._counter_lock = threading.Lock()
        self._router_thread: Optional[threading.Thread] = None
        self._router_stop = threading.Event()
        self._started = False
        self._scaler = None              # attached ScalingController

    @property
    def engines(self) -> Dict[str, Any]:
        """Replica-0 view of the stage engines (single-replica compat:
        the sync backend, pre-start admission and tick() use it)."""
        return {n: lst[0] for n, lst in self.stage_replicas.items()}

    def _live_engines(self, name: str) -> List[Any]:
        if self._started and name in self._workers:
            return self._workers[name].engines
        return self.stage_replicas[name]

    # ------------------------------------------------------------------
    def _sp(self, req: Request) -> SamplingParams:
        return (SamplingParams(**req.sampling) if req.sampling
                else SamplingParams())

    def submit(self, request: Request) -> None:
        """Admit one request: its initial inputs go to every source stage.
        Callable at any time while the threaded backend is serving."""
        with self._lock:
            self.requests[request.req_id] = request
            self._outputs_pending[request.req_id] = set(
                self.graph.output_stages())
        for src in self.graph.sources():
            if self._started:
                ok = self._workers[src].submit(StageInput(
                    request, self._sp(request), inputs=request.inputs))
                if not ok:
                    self._fail(request, f"admission to {src!r} rejected")
            elif src in self._proc_replicas:
                # the parent-side engine of a process stage never steps;
                # hold the admission until start() spawns the workers
                with self._lock:
                    self._deferred.append((src, request))
            else:
                request.mark_stage_start(src)
                self.engines[src].enqueue(
                    request.req_id, request.inputs, self._sp(request),
                    request.data)

    # ------------------------------------------------------------------
    # threaded backend lifecycle
    # ------------------------------------------------------------------
    def _stage_policy(self, name: str) -> RoutingPolicy:
        """Per-stage routing override from the config; stages without one
        share the orchestrator-wide policy instance."""
        r = self.config.stage_routing(name)
        if isinstance(r, RoutingPolicy):
            return r
        if r == self.routing.name:
            return self.routing
        return make_routing_policy(r)

    def start(self) -> None:
        """Spin up one replica set (N worker threads, or N spawned worker
        processes for process-isolated stages) per stage plus the router
        thread."""
        if self.backend != "threaded":
            raise RuntimeError("start() requires backend='threaded'")
        if self._started:
            return
        if self._seed_connector is None and self.warm_seed:
            # warm-seed snapshots ride the connector channel API; the
            # cross-process data plane serves thread and process
            # receivers alike (manifest route for the latter)
            from repro.connector.shm import SharedMemoryConnector
            self._seed_connector = SharedMemoryConnector(
                cross_process=shm_transport.available())
        self._router_stop = threading.Event()
        self._workers = {}
        for name in self.graph.stages:
            sc = self.config.stage(name)
            self._workers[name] = ReplicaSet(
                name, self.stage_replicas[name], self._emit,
                capacity=self.queue_capacity,
                metrics_bank=self._stage_metrics[name],
                policy=self._stage_policy(name),
                engine_factory=self.engine_factories.get(name),
                warm_seed=self.warm_seed,
                isolation=sc.isolation,
                engine_spec=self.engine_specs.get(name),
                seed_connector=self._seed_connector,
                n_replicas=self._proc_replicas.get(name))
        self._started = True
        for w in self._workers.values():
            w.start()
        self._router_thread = threading.Thread(
            target=self._router_loop, name="stage-router", daemon=True)
        self._router_thread.start()
        with self._lock:
            deferred, self._deferred = self._deferred, []
        for src, request in deferred:
            ok = self._workers[src].submit(StageInput(
                request, self._sp(request), inputs=request.inputs))
            if not ok:
                self._fail(request, f"admission to {src!r} rejected")

    # ------------------------------------------------------------------
    # dynamic scaling (called by the ScalingController's thread)
    # ------------------------------------------------------------------
    def replica_counts(self) -> Dict[str, int]:
        return {n: (self._workers[n].n_replicas
                    if self._started and n in self._workers
                    else self._proc_replicas.get(
                        n, len(self.stage_replicas[n])))
                for n in self.graph.stages}

    def scale_up(self, stage: str, engine: Any = None) -> bool:
        """Add one replica to ``stage`` (needs an engine or a factory;
        process-isolated stages spawn one from the engine spec)."""
        if self._started and stage in self._workers:
            return self._workers[stage].scale_up(engine) is not None
        if stage in self._proc_replicas:
            self._proc_replicas[stage] += 1
            return True
        if engine is None:
            fac = self.engine_factories.get(stage)
            if fac is None:
                return False
            engine = fac()
        self.stage_replicas[stage].append(engine)
        return True

    def scale_down(self, stage: str, drain: bool = True) -> bool:
        """Retire the least-loaded replica of ``stage`` (never below one);
        with drain=True its queued and admitted work completes first."""
        if self._started and stage in self._workers:
            return self._workers[stage].scale_down(drain=drain) is not None
        if stage in self._proc_replicas:
            if self._proc_replicas[stage] <= 1:
                return False
            self._proc_replicas[stage] -= 1
            return True
        if len(self.stage_replicas[stage]) <= 1:
            return False
        self.stage_replicas[stage].pop()
        return True

    def _emit(self, stage: str, ev: StageEvent) -> None:
        with self._counter_lock:
            self._unrouted += 1
        self._events.put((stage, ev))

    def _router_loop(self) -> None:
        while True:
            try:
                stage, ev = self._events.get(timeout=0.01)
            except queue.Empty:
                if self._router_stop.is_set():
                    break
                continue
            try:
                self._route(ev)
            except Exception as e:  # noqa: BLE001 — isolate to the request
                with self._lock:
                    req = self.requests.get(ev.req_id)
                if req is not None:
                    self._fail(req, f"router: {type(e).__name__}: {e}")
            finally:
                with self._counter_lock:
                    self._unrouted -= 1

    @property
    def worker_error(self) -> Optional[str]:
        """First fatal stage-engine failure, if any — online front-ends
        should poll this instead of waiting out their time limit."""
        return next((w.error for w in self._workers.values() if w.error),
                    None)

    def _quiescent(self) -> bool:
        with self._counter_lock:
            if self._unrouted:
                return False
        if any(w.active or not w.inbox_empty()
               for w in self._workers.values()):
            return False
        return not any(e.has_work for n in self.graph.stages
                       for e in self._live_engines(n))

    def drain(self, timeout: Optional[float] = None,
              poll: float = 0.005) -> bool:
        """Block until every submitted request completed (True) or the
        system quiesces with requests still unfinished / timeout (False)."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        quiet = 0
        while True:
            with self._lock:
                done = all(r.completion_time is not None
                           for r in self.requests.values())
            if done:
                return True
            if self.worker_error:
                raise RuntimeError(
                    f"stage worker died: {self.worker_error}")
            if deadline is not None and time.perf_counter() > deadline:
                return False
            # a request can legitimately never complete (e.g. a transfer fn
            # filtered its only event) — exit once nothing is in flight,
            # like the lock-step loop's "engines idle" exit
            if self._quiescent():
                quiet += 1
                if quiet >= 3:
                    return False
            else:
                quiet = 0
            time.sleep(poll)

    def shutdown(self, drain: bool = True) -> None:
        """Stop workers (upstream-first when draining, so final events
        cascade downstream) and then the router."""
        if not self._started:
            return
        if self._scaler is not None:         # no scaling mid-teardown
            self._scaler.stop()
            self._scaler.join(timeout=30.0)
            self._scaler = None
        for name in self.graph.topo_order():
            w = self._workers[name]
            w.stop(drain=drain)
            w.join(timeout=30.0)
            while drain:  # flush this stage's last events downstream
                with self._counter_lock:
                    if self._unrouted == 0:
                        break
                time.sleep(0.002)
        # persist any runtime scaling into the engine bindings so a
        # restart reopens with the same replica topology (process sets
        # persist their spawn count — the proxies die with the children)
        for name, w in self._workers.items():
            if w.isolation == "process":
                self._proc_replicas[name] = w.n_replicas
            else:
                self.stage_replicas[name] = w.engines
        self._router_stop.set()
        if self._router_thread is not None:
            self._router_thread.join(timeout=30.0)
        self._started = False

    # ------------------------------------------------------------------
    # routing (runs on the router thread, or on the caller in sync mode)
    # ------------------------------------------------------------------
    def _forget_request(self, req_id: int) -> None:
        """Release per-request routing state: edge chunk-seq counters and
        the replica sets' sticky chunk-stream pins."""
        for k in [k for k in self._edge_seq if k[1] == req_id]:
            self._edge_seq.pop(k, None)
        for w in self._workers.values():
            w.forget(req_id)

    def _fail(self, req: Request, msg: str) -> None:
        with self._lock:
            if req.completion_time is not None:
                req.failed = req.failed or msg
                return
            req.failed = msg
            req.completion_time = time.perf_counter()
            self._outputs_pending.pop(req.req_id, None)
            self.completed.append(req)
        self._forget_request(req.req_id)
        self.completions.put(req)

    def _finish(self, req: Request) -> None:
        with self._lock:
            req.completion_time = time.perf_counter()
            self._outputs_pending.pop(req.req_id, None)
            self.completed.append(req)
        self._forget_request(req.req_id)
        self.completions.put(req)

    @staticmethod
    def _apply_transfer(edge, req: Request, payload, kind: str,
                        chunk_index: int, is_last: bool):
        """Edge transfer + chunk metadata defaulting — the ONE place both
        the sync path and the worker-side resolve closure go through."""
        inputs = edge.transfer(req.data, payload)
        if inputs is None:
            return None                       # transfer fn filtered this event
        if kind == "chunk":
            inputs.setdefault("chunk_index", chunk_index)
            inputs.setdefault("is_last_chunk", is_last)
        return inputs

    def _forward(self, edge, req: Request, ev: StageEvent) -> None:
        conn = self.connectors[edge.connector]
        eid = StageGraph.edge_id(edge)
        key = f"{eid}/{req.req_id}/{ev.chunk_index}"
        self._transfer_log.append({
            "edge": eid, "connector": edge.connector, "req_id": req.req_id})
        if self._started:
            # upstream side publishes; the destination worker receives,
            # deserializes and applies the transfer in ITS thread
            conn.send(key, ev.payload)
            kind, chunk_index, is_last = ev.kind, ev.chunk_index, ev.is_last
            recv_timeout = self.recv_timeout

            def resolve(conn=conn, key=key, edge=edge, req=req, kind=kind,
                        chunk_index=chunk_index, is_last=is_last, eid=eid):
                try:
                    payload = conn.recv(key, timeout=recv_timeout)
                except TransferTimeout as e:
                    # tag the edge so the per-request failure is
                    # attributable (the worker catches + emits an error
                    # event; the worker itself keeps serving)
                    raise e.with_edge(eid) from None
                finally:
                    conn.release(key)
                return self._apply_transfer(edge, req, payload, kind,
                                            chunk_index, is_last)

            item = StageInput(req, self._sp(req), resolve=resolve,
                              origin=f"transfer {eid}",
                              cleanup=lambda: conn.release(key))
            if edge.streaming and kind == "chunk":
                # stamp the connector-boundary sequence number: the
                # destination worker asserts per-request FIFO on it and
                # the replica set pins the stream to one replica
                sk = (eid, req.req_id)
                item.seq = self._edge_seq.get(sk, -1) + 1
                self._edge_seq[sk] = item.seq
                item.seq_last = is_last
                if is_last:
                    self._edge_seq.pop(sk, None)
            t0 = time.perf_counter()
            ok = self._workers[edge.dst].submit(item)
            es = self.edge_stats[eid]
            es["transfers"] += 1
            es["backpressure_s"] += time.perf_counter() - t0
            if not ok:
                conn.release(key)             # never delivered: end lifetime
                self._fail(req, f"{eid}: downstream worker unavailable")
            return
        # ---- sync (lock-step) path ----
        conn.send(key, ev.payload)
        try:
            payload = conn.recv(key, timeout=self.recv_timeout)
        except Exception as e:    # noqa: BLE001 — fail the request, not run()
            self._fail(req, f"{eid}: transfer {type(e).__name__}: {e}")
            return
        finally:
            conn.release(key)     # either way the key's lifetime ends here
        self.edge_stats[eid]["transfers"] += 1
        try:
            inputs = self._apply_transfer(edge, req, payload, ev.kind,
                                          ev.chunk_index, ev.is_last)
        except Exception as e:
            # a broken user transfer fn fails THIS request, not the
            # serving loop: mark failed + complete so callers unblock
            self._fail(req, f"transfer {eid}: {type(e).__name__}: {e}")
            return
        if inputs is None:
            return
        req.mark_stage_start(edge.dst)
        self.engines[edge.dst].enqueue(req.req_id, inputs, self._sp(req),
                                       req.data)

    def _route(self, ev: StageEvent) -> None:
        with self._lock:
            req = self.requests.get(ev.req_id)
        if req is None:
            return                            # unknown/forgotten request
        stage = ev.stage
        if ev.kind == "error":
            # fault isolation: the failing stage input killed one request
            self._fail(req, str(ev.payload.get("error", "stage error")))
            return
        if ev.kind == "finished":
            req.mark_stage_end(stage)
        for edge in self.graph.out_edges(stage):
            if ev.kind == "chunk" and not edge.streaming:
                continue                      # non-streaming edges wait
            if ev.kind == "finished" and edge.streaming and ev.payload.get(
                    "n_chunks", 0) > 0:
                continue                      # chunks already forwarded
            if req.completion_time is not None and req.failed:
                break                         # request already failed
            self._forward(edge, req, ev)

        # terminal output collection (under the lock: _fail() may pop
        # the pending-outputs entry from another thread at any moment;
        # _finish() runs after release so completions.put stays unlocked)
        done = False
        with self._lock:
            outs = self._outputs_pending.get(ev.req_id)
            if outs is None or stage not in outs:
                return
            if req.first_output_time is None:
                req.first_output_time = time.perf_counter()
            if ev.kind == "finished" or (ev.kind == "chunk" and ev.is_last):
                req.outputs.setdefault(stage, []).append(ev.payload)
                req.mark_stage_end(stage)
                outs.discard(stage)
                done = not outs
            elif ev.kind == "chunk":
                req.outputs.setdefault(stage, []).append(ev.payload)
        if done:
            self._finish(req)

    # ------------------------------------------------------------------
    # lock-step compat path
    # ------------------------------------------------------------------
    def tick(self) -> int:
        """Step every engine once; returns number of events processed.
        Only valid while the threaded backend is NOT running."""
        if self._started:
            raise RuntimeError(
                "tick() is the lock-step path; shutdown() the threaded "
                "backend first")
        n = 0
        for name in self.graph.topo_order():
            for ev in self.engines[name].step():
                ev.stage = ev.stage or name
                self._route(ev)
                n += 1
        return n

    def run(self, max_ticks: int = 100_000,
            timeout: Optional[float] = None) -> List[Request]:
        """Compatibility path: drain everything submitted so far and
        return the completed requests (offline inference)."""
        if self.backend == "sync":
            for _ in range(max_ticks):
                if all(r.completion_time is not None
                       for r in self.requests.values()):
                    break
                busy = any(self.engines[n].has_work
                           for n in self.graph.stages)
                self.tick()
                if not busy:
                    break
            return self.completed
        self.start()
        try:
            self.drain(timeout=timeout)
        finally:
            # always tear the threads down, even when drain() raises on a
            # dead worker — otherwise the backend stays _started forever
            self.shutdown(drain=False)
        return self.completed

    # ------------------------------------------------------------------
    def stage_busy_times(self) -> Dict[str, float]:
        return {n: sum(getattr(e, "busy_time", 0.0)
                       for e in self._live_engines(n))
                for n in self.graph.stages}

    def _replica_snapshots(self, name: str) -> Dict[int, Dict[str, float]]:
        """Per-replica metric snapshots, including retired replica ids
        whose counters still contribute to the stage totals."""
        if self._started and name in self._workers:
            live = {rid: w.engine for rid, w in self._workers[name].workers()}
        elif name in self._proc_replicas:
            # not serving: the children are gone, only the spawn count
            # survives (busy seconds were banked at retirement)
            live = {rid: None for rid in range(self._proc_replicas[name])}
        else:
            live = dict(enumerate(self.stage_replicas[name]))
        out = {}
        for rid, metrics in sorted(self._stage_metrics[name].items()):
            eng = live.get(rid)
            snap = metrics.snapshot(
                busy_time=getattr(eng, "busy_time", 0.0) if eng else 0.0)
            snap["live"] = 1.0 if rid in live else 0.0
            out[rid] = snap
        if not out:                       # never served: synthesize rows
            for rid, eng in live.items():
                out[rid] = WorkerMetrics().snapshot(
                    busy_time=getattr(eng, "busy_time", 0.0))
                out[rid]["live"] = 1.0
        return out

    def _aggregate_stage(self, name: str) -> Dict[str, float]:
        """Merge the per-replica snapshots into one stage row: counters
        sum, inbox high-water maxes, busy_frac is busy over summed active
        spans (per-replica capacity), throughput adds, and queue-delay
        percentiles are recomputed over the merged raw samples."""
        reps = self._replica_snapshots(name)
        agg: Dict[str, float] = {}
        for c in ("admitted", "filtered", "finished", "events", "steps",
                  "errors", "order_violations", "replica_failures",
                  "busy_time", "finished_per_s"):
            agg[c] = sum(r[c] for r in reps.values())
        agg["max_inbox_depth"] = max(
            (r["max_inbox_depth"] for r in reps.values()), default=0)
        span = sum(r["active_span"] for r in reps.values())
        agg["active_span"] = span
        agg["busy_frac"] = agg["busy_time"] / span if span > 0 else 0.0
        qd = np.concatenate([
            np.asarray(m.raw_delays(), np.float64)
            for m in self._stage_metrics[name].values()]) \
            if self._stage_metrics[name] else np.empty(0)
        agg["queue_delay_mean"] = float(qd.mean()) if qd.size else 0.0
        agg["queue_delay_p50"] = (float(np.percentile(qd, 50))
                                  if qd.size else 0.0)
        agg["queue_delay_p95"] = (float(np.percentile(qd, 95))
                                  if qd.size else 0.0)
        agg["n_replicas"] = sum(1 for r in reps.values() if r["live"])
        return agg

    def stage_metrics(self) -> Dict[str, Dict[str, float]]:
        """Per-stage serving metrics: queueing delay, busy fraction,
        throughput, inbox high-water mark, prefix-cache hit rates —
        aggregated across replicas, with the per-replica rows under
        ``"replicas"`` when a stage runs more than one."""
        out = {}
        for n in self.graph.stages:
            m = self._aggregate_stage(n)
            cached = computed = lookups = hits = 0
            full_blk = part = 0
            for eng in self._live_engines(n):
                ps = getattr(eng, "prefix_stats", None)
                if ps is not None:
                    lookups += ps.get("lookups", 0)
                    hits += ps.get("hits", 0)
                    cached += ps.get("cached_tokens", 0)
                    computed += ps.get("computed_tokens", 0)
                    full_blk += ps.get("full_block_tokens", 0)
                    part += ps.get("partial_tokens", 0)
            if lookups:
                total = cached + computed
                m["cached_tokens"] = cached
                m["computed_tokens"] = computed
                m["full_block_tokens"] = full_blk
                m["partial_tokens"] = part
                m["prefix_hit_rate"] = cached / total if total else 0.0
                m["full_hit_rate"] = full_blk / total if total else 0.0
                m["partial_hit_rate"] = part / total if total else 0.0
            if m["n_replicas"] > 1 or len(self._stage_metrics[n]) > 1:
                m["replicas"] = self._replica_snapshots(n)
            out[n] = m
        return out

    def connector_stats(self) -> Dict[str, Any]:
        return {k: c.stats for k, c in self.connectors.items()}
