"""Request objects flowing through a stage graph.

Each request carries the paper's "predefined dictionary for storing
intermediate per-request data" (§3.3): transfer functions and per-stage
preprocess functions read and update ``request.data``.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

_req_counter = itertools.count()


@dataclass
class Request:
    inputs: Dict[str, Any]                    # initial model inputs
    req_id: int = field(default_factory=lambda: next(_req_counter))
    sampling: Dict[str, Any] = field(default_factory=dict)
    # the unified per-request data dict (paper §3.3): intermediate tensors
    # (hidden states, codec tokens, embeddings) keyed by producer stage.
    data: Dict[str, Any] = field(default_factory=dict)
    # telemetry
    arrival_time: float = field(default_factory=time.perf_counter)
    completion_time: Optional[float] = None
    first_output_time: Optional[float] = None   # TTFT of the FINAL output
    stage_spans: Dict[str, List[float]] = field(default_factory=dict)
    # per-stage queueing delays (submit -> engine admission), seconds; a
    # stage fed by a streaming edge collects one sample per chunk
    queue_delays: Dict[str, List[float]] = field(default_factory=dict)
    # final outputs per output-stage
    outputs: Dict[str, Any] = field(default_factory=dict)
    failed: Optional[str] = None

    def mark_stage_start(self, stage: str) -> None:
        self.stage_spans.setdefault(stage, [time.perf_counter(), None])

    def mark_stage_end(self, stage: str) -> None:
        span = self.stage_spans.setdefault(stage, [time.perf_counter(), None])
        span[1] = time.perf_counter()

    @property
    def jct(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    def stage_time(self, stage: str) -> float:
        span = self.stage_spans.get(stage)
        if not span or span[1] is None:
            return 0.0
        return span[1] - span[0]

    def note_queue_delay(self, stage: str, delay: float) -> None:
        self.queue_delays.setdefault(stage, []).append(delay)

    def queue_delay(self, stage: str) -> float:
        """Total time this request spent queued in front of ``stage``."""
        return float(sum(self.queue_delays.get(stage, ())))


@dataclass
class StageEvent:
    """Emitted by engines: a finished stage output or a streamed chunk."""
    req_id: int
    kind: str                 # "finished" | "chunk"
    payload: Any
    stage: str = ""
    chunk_index: int = 0
    is_last: bool = False
