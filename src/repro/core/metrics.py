"""Serving metrics: JCT / TTFT / throughput summaries over completed
requests (the quantities the paper's §4 tables report)."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.request import Request


def _pct(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")


def summarize(requests: List[Request], wall_time: Optional[float] = None,
              audio_frames: Optional[int] = None,
              frame_seconds: float = 0.02) -> Dict[str, float]:
    jcts = [r.jct for r in requests if r.jct is not None]
    ttfts = [r.first_output_time - r.arrival_time for r in requests
             if r.first_output_time is not None]
    out = {
        "n": len(requests),
        "jct_mean": float(np.mean(jcts)) if jcts else float("nan"),
        "jct_p50": _pct(jcts, 50),
        "jct_p95": _pct(jcts, 95),
        "ttft_p50": _pct(ttfts, 50),
        "ttft_p95": _pct(ttfts, 95),
    }
    if wall_time:
        out["req_per_s"] = len(jcts) / wall_time
    if audio_frames:
        out["rtf_mean"] = out["jct_mean"] / (audio_frames * frame_seconds)
    return out


def summarize_queueing(requests: List[Request]) -> Dict[str, Dict[str, float]]:
    """Per-stage queueing delay (submit -> engine admission) percentiles
    over a set of requests — the §3.1 disaggregation win shows up here:
    a slow stage's queue grows while other stages' delays stay flat."""
    per_stage: Dict[str, List[float]] = {}
    for r in requests:
        for stage, delays in r.queue_delays.items():
            per_stage.setdefault(stage, []).append(float(sum(delays)))
    return {stage: {
        "mean": float(np.mean(ds)),
        "p50": _pct(ds, 50),
        "p95": _pct(ds, 95),
        "max": float(np.max(ds)),
    } for stage, ds in per_stage.items()}


def _report_row(label: str, m: Dict[str, float], cols: List[str]) -> str:
    cells = []
    for c in cols:
        v = m.get(c, 0)
        cells.append((f"{v:.4f}" if isinstance(v, float)
                      else str(v)).rjust(18))
    return label.ljust(12) + "".join(cells)


def stage_report(stage_metrics: Dict[str, Dict[str, float]]) -> str:
    """Render Orchestrator.stage_metrics() as an aligned text table.
    Multi-replica stages get one aggregate row plus an indented
    ``stage/<rid>`` sub-row per replica (retired ids keep their row —
    their counters are still part of the aggregate)."""
    cols = ["admitted", "finished", "steps", "busy_time", "busy_frac",
            "finished_per_s", "queue_delay_p50", "queue_delay_p95",
            "max_inbox_depth"]
    if any("prefix_hit_rate" in m for m in stage_metrics.values()):
        cols += ["cached_tokens", "computed_tokens", "full_block_tokens",
                 "partial_tokens", "prefix_hit_rate"]
    # only widen the table when a process replica actually died
    if any(m.get("replica_failures") for m in stage_metrics.values()):
        cols += ["replica_failures"]
    head = "stage".ljust(12) + "".join(c.rjust(18) for c in cols)
    lines = [head]
    for stage, m in stage_metrics.items():
        lines.append(_report_row(stage, m, cols))
        for rid, rm in sorted(m.get("replicas", {}).items()):
            mark = "" if rm.get("live") else " (retired)"
            lines.append(_report_row(f" {stage}/{rid}{mark}", rm, cols))
    return "\n".join(lines)
