"""Serving metrics: JCT / TTFT / throughput summaries over completed
requests (the quantities the paper's §4 tables report)."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.request import Request


def _pct(xs: List[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if xs else float("nan")


def summarize(requests: List[Request], wall_time: Optional[float] = None,
              audio_frames: Optional[int] = None,
              frame_seconds: float = 0.02) -> Dict[str, float]:
    jcts = [r.jct for r in requests if r.jct is not None]
    ttfts = [r.first_output_time - r.arrival_time for r in requests
             if r.first_output_time is not None]
    out = {
        "n": len(requests),
        "jct_mean": float(np.mean(jcts)) if jcts else float("nan"),
        "jct_p50": _pct(jcts, 50),
        "jct_p95": _pct(jcts, 95),
        "ttft_p50": _pct(ttfts, 50),
        "ttft_p95": _pct(ttfts, 95),
    }
    if wall_time:
        out["req_per_s"] = len(jcts) / wall_time
    if audio_frames:
        out["rtf_mean"] = out["jct_mean"] / (audio_frames * frame_seconds)
    return out
