"""Process-isolated stage worker (spawn-based StageWorker contract).

A :class:`ProcessStageWorker` serves the same contract as the in-thread
:class:`~repro.core.worker.StageWorker` — bounded inbox, ``submit`` /
``start`` / ``stop(drain)`` / ``join`` lifecycle, shared
:class:`~repro.core.worker.WorkerMetrics` — but runs its engine in a
**spawned child process**, so a stage gets real OS-level isolation (its
own interpreter, its own jax runtime, no GIL sharing with siblings).

Split of responsibilities across the boundary:

  - control plane: two spawn-context queues.  Parent→child carries
    ``item`` / ``seed`` / ``snapshot`` / ``stop`` commands; child→parent
    carries ``ready`` / ``hb`` (heartbeat + status) / ``admit`` / ``ev``
    (StageEvents) / RPC replies / ``err`` / ``bye``.
  - data plane: tensor payloads never ride the pipes.  The parent-side
    *feeder* thread resolves each item (connector ``recv`` + edge
    transfer run in the parent, where the connectors live), writes the
    result into a named shared-memory segment and ships only the
    picklable :class:`~repro.connector.shm_transport.SegmentManifest`.
  - engines: a closure over initialized params cannot cross ``spawn``;
    the child rebuilds its engine from a picklable
    :class:`~repro.core.config.EngineSpec` (deterministic builders give
    byte-identical params from the same seed).

Failure semantics: the parent *pump* thread detects a dead child (exit)
or a wedged one (no heartbeat within ``heartbeat_timeout``) and hands
every in-flight item — shipped-but-unfinished (the ledger) plus anything
still in the parent inbox — to the ``on_failure`` callback, which the
owning :class:`~repro.core.worker.ReplicaSet` uses to re-admit them to
surviving replicas.  Delivery is therefore at-least-once across a
replica failure: a request whose chunks were partially emitted may
re-emit them after re-admission, but no submitted request is lost.  A
child-side *engine* crash (build or ``step`` raising) instead surfaces
through ``.error`` like a thread worker's fatal engine failure.

This module is import-light (no jax): the parent pays nothing extra and
a child serving a stub engine never imports jax at all.
"""
from __future__ import annotations

import multiprocessing as mp
import queue
import threading
import time
import traceback
from collections import OrderedDict
from dataclasses import asdict, is_dataclass
from types import SimpleNamespace
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.connector import shm_transport
from repro.core.config import EngineSpec
from repro.core.request import StageEvent
from repro.core.worker import StageInput, WorkerMetrics

_JOIN_GRACE = 5.0


def available() -> bool:
    """True when spawn + named shared memory work on this platform."""
    if not shm_transport.available():
        return False
    try:
        mp.get_context("spawn")
    except ValueError:               # pragma: no cover — exotic platform
        return False
    return True


# ---------------------------------------------------------------------------
# sampling across the boundary
# ---------------------------------------------------------------------------

def _pack_sampling(s: Any) -> Tuple[str, Any]:
    """SamplingParams lives in a jax-importing module; shipping the
    instance would drag jax into every child.  A SimpleNamespace with the
    same fields duck-types it (engines only read attributes), so stub
    children stay jax-free."""
    if is_dataclass(s) and not isinstance(s, type):
        return ("ns", asdict(s))
    return ("raw", s)


def _unpack_sampling(spec: Tuple[str, Any]) -> Any:
    tag, val = spec
    if tag == "ns":
        return SimpleNamespace(**val)
    return val


# ---------------------------------------------------------------------------
# child process
# ---------------------------------------------------------------------------

def _child_status(engine: Any, consumed: int, steps: int) -> Dict[str, Any]:
    ps = getattr(engine, "prefix_stats", None)
    return {
        "consumed": consumed,
        "has_work": bool(getattr(engine, "has_work", False)),
        "queue_depth": int(getattr(engine, "queue_depth", 0)),
        "busy_time": float(getattr(engine, "busy_time", 0.0)),
        "steps": steps,
        "cached_prefix_pages": int(
            getattr(engine, "cached_prefix_pages", 0) or 0),
        "prefix_stats": dict(ps) if isinstance(ps, dict) else None,
    }


def _child_admit(engine: Any, stage: str, evt_q: Any, msg: tuple) -> None:
    _, item_id, req_id, origin, sp_spec, t_submit, manifest = msg
    try:
        payload = shm_transport.read_and_release(manifest)
        evt_q.put(("admit", item_id, req_id,
                   time.perf_counter() - t_submit))
        engine.enqueue(req_id, payload["inputs"],
                       _unpack_sampling(sp_spec), payload["data"])
    except Exception as e:           # noqa: BLE001 — fault isolation
        evt_q.put(("aerr", StageEvent(
            req_id, "error",
            {"error": f"{origin}: {type(e).__name__}: {e}"}, stage=stage)))


def _child_seed(engine: Any, manifest: Any, release: bool) -> Optional[int]:
    """Seed the child engine's prefix index from a shipped snapshot.
    ``release=False`` when a connector on the parent side still owns the
    segment's lifetime (manifest-routed warm seed)."""
    try:
        payload = (shm_transport.read_and_release(manifest) if release
                   else shm_transport.read_manifest(manifest))
        if not hasattr(engine, "seed_prefixes"):
            return None
        return int(engine.seed_prefixes(payload["paths"]))
    except Exception:                # noqa: BLE001 — advisory
        return None


def _child_snapshot(engine: Any, max_pages: int) -> Optional[Any]:
    try:
        if not hasattr(engine, "prefix_snapshot"):
            return None
        try:
            paths = engine.prefix_snapshot(max_pages=max_pages)
        except TypeError:            # builder without the kwarg
            paths = engine.prefix_snapshot()
        seg, manifest = shm_transport.write_segment({"paths": paths})
        if seg is not None:
            seg.close()              # receiver unlinks
        return manifest
    except Exception:                # noqa: BLE001 — advisory
        return None


def _child_main(spec: EngineSpec, stage: str, cmd_q: Any, evt_q: Any,
                hb_interval: float) -> None:
    """Spawn entry point: rebuild the engine, then run the admit/step
    loop, mirroring ``StageWorker._loop`` on the far side of the pipe."""
    try:
        engine = spec.build()
    except BaseException:            # noqa: BLE001 — report, don't hang
        evt_q.put(("err", f"engine build failed:\n"
                          f"{traceback.format_exc()}"))
        return
    consumed = steps = 0
    stopping, drain = False, True
    last_hb = 0.0
    evt_q.put(("ready", _child_status(engine, consumed, steps)))
    while True:
        activity = False
        while True:                  # drain the command queue
            try:
                if not getattr(engine, "has_work", False) and not stopping:
                    msg = cmd_q.get(timeout=hb_interval)
                else:
                    msg = cmd_q.get_nowait()
            except queue.Empty:
                break
            kind = msg[0]
            if kind == "item":
                activity = True
                consumed += 1
                if stopping and not drain:
                    shm_transport.release_manifest(msg[6])
                else:
                    _child_admit(engine, stage, evt_q, msg)
            elif kind == "seed":
                activity = True
                n = _child_seed(engine, msg[1], msg[2])
                # fresh status BEFORE the reply (same FIFO queue): when
                # the parent's RPC returns, cached_prefix_pages already
                # reflects the seed — an immediate scale_up sees a warm
                # donor instead of racing the next heartbeat
                evt_q.put(("hb", _child_status(engine, consumed, steps)))
                evt_q.put(("seeded", n))
            elif kind == "snapshot":
                activity = True
                evt_q.put(("snap", _child_snapshot(engine, msg[1])))
            elif kind == "stop":
                stopping, drain = True, bool(msg[1])
        if stopping and (not drain
                         or not getattr(engine, "has_work", False)):
            break
        if getattr(engine, "has_work", False):
            try:
                events = engine.step()
            except BaseException:    # noqa: BLE001 — engine died
                evt_q.put(("err", f"engine.step failed:\n"
                                  f"{traceback.format_exc()}"))
                return
            steps += 1
            activity = True
            for ev in events:
                ev.stage = ev.stage or stage
                evt_q.put(("ev", ev))
        now = time.perf_counter()
        if activity or now - last_hb >= hb_interval:
            # every state change rides a fresh status (consumed count and
            # has_work travel atomically, so the parent's quiescence view
            # never shows "acked but idle" for work the engine still holds)
            evt_q.put(("hb", _child_status(engine, consumed, steps)))
            last_hb = now
    evt_q.put(("bye", _child_status(engine, consumed, steps)))


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------

class RemoteEngineProxy:
    """Engine-shaped view of a process replica for the parent-side code
    that introspects engines (routing policies, metrics aggregation,
    warm seeding).  Backed by the child's last heartbeat status; the
    ``prefix_snapshot`` / ``seed_prefixes`` pair round-trips through the
    control queue + a shared-memory segment.  ``prefix_hint`` returns 0
    (the affinity probe is not proxied across the boundary — affinity
    routing degrades to least-loaded for process stages)."""

    def __init__(self, worker: "ProcessStageWorker") -> None:
        self._w = worker

    @property
    def has_work(self) -> bool:
        w = self._w
        return w.pending > 0 or bool(w.status["has_work"])

    @property
    def queue_depth(self) -> int:
        w = self._w
        return w.pending + int(w.status["queue_depth"])

    @property
    def busy_time(self) -> float:
        return float(self._w.status["busy_time"])

    @property
    def cached_prefix_pages(self) -> int:
        return int(self._w.status["cached_prefix_pages"])

    @property
    def prefix_stats(self) -> Optional[dict]:
        return self._w.status.get("prefix_stats")

    def prefix_hint(self, hashes: Any) -> int:
        return 0

    def prefix_snapshot(self, max_pages: int = 64) -> list:
        return self._w.prefix_snapshot(max_pages=max_pages) or []

    def seed_prefixes(self, snapshot: Any) -> int:
        return int(self._w.seed_snapshot(snapshot) or 0)

    def enqueue(self, *a: Any, **k: Any) -> None:
        raise RuntimeError(
            "process-isolated stage: admit through worker.submit(), the "
            "engine lives in a child process")


class ProcessStageWorker:
    """Runs one stage engine in a spawned child process; same contract
    as :class:`~repro.core.worker.StageWorker` from the router's side."""

    isolation = "process"
    _IDLE_WAIT = 0.02

    def __init__(self, name: str, spec: EngineSpec,
                 emit: Callable[[str, StageEvent], None], *,
                 capacity: int = 64,
                 metrics: Optional[WorkerMetrics] = None,
                 label: Optional[str] = None,
                 on_failure: Optional[Callable[..., None]] = None,
                 heartbeat_timeout: float = 60.0,
                 ready_timeout: float = 180.0,
                 heartbeat_interval: float = 0.2) -> None:
        if not available():
            raise RuntimeError(
                "process isolation needs spawn + "
                "multiprocessing.shared_memory")
        self.name = name
        self.label = label or name
        self.spec = spec
        self.emit = emit
        self.capacity = capacity
        self.inbox: "queue.Queue[Optional[StageInput]]" = queue.Queue(
            maxsize=capacity)
        self.metrics = metrics or WorkerMetrics()
        self.on_failure = on_failure
        self.heartbeat_timeout = heartbeat_timeout
        self.ready_timeout = ready_timeout
        self.error: Optional[str] = None     # fatal child ENGINE failure
        self.failed = False                  # replica death (kill/wedge)
        self.failure_reason: Optional[str] = None
        self.engine = RemoteEngineProxy(self)
        #: child's last reported status (atomically replaced by the pump)
        self.status: Dict[str, Any] = {
            "consumed": 0, "has_work": False, "queue_depth": 0,
            "busy_time": 0.0, "steps": 0, "cached_prefix_pages": 0,
            "prefix_stats": None}
        self._last_seq: Dict[int, int] = {}
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._started = False
        self._finalized = False
        self._feeding = False
        self._ready = threading.Event()
        self._gone = threading.Event()
        # item_id -> (re-admittable StageInput, shipped manifest); holds
        # resolved inputs until the request reaches a terminal event at
        # this stage, which is exactly what failure re-admission replays
        # guarded-by: _ledger_lock
        self._ledger: "OrderedDict[int, Tuple[StageInput, Any]]" = \
            OrderedDict()
        self._ledger_lock = threading.Lock()
        self._next_item = 0
        self._shipped = 0
        self._rpc_lock = threading.Lock()
        self._rpc_replies: "queue.Queue[tuple]" = queue.Queue()
        ctx = mp.get_context("spawn")
        self._cmd = ctx.Queue()
        self._evt = ctx.Queue()
        self._proc = ctx.Process(
            target=_child_main,
            args=(spec, name, self._cmd, self._evt, heartbeat_interval),
            name=f"stage-{self.label}", daemon=True)
        self._feeder = threading.Thread(
            target=self._feed, name=f"stage-{self.label}-feed", daemon=True)
        self._pump = threading.Thread(
            target=self._pump_loop, name=f"stage-{self.label}-pump",
            daemon=True)
        self._t_start = 0.0
        self._last_msg = 0.0

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        self._t_start = self._last_msg = time.perf_counter()
        self._proc.start()
        self._feeder.start()
        self._pump.start()

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until the child built its engine (warm-seed RPCs and
        latency-sensitive tests want a live child)."""
        return self._ready.wait(timeout)

    def stop(self, drain: bool = True) -> None:
        self._drain_on_stop = drain
        self._stop.set()
        try:                                 # wake an idle-blocked feeder
            self.inbox.put_nowait(None)
        except queue.Full:
            pass

    def join(self, timeout: Optional[float] = None) -> None:
        if not self._started:
            return
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)

        def left() -> Optional[float]:
            return (None if deadline is None
                    else max(0.0, deadline - time.perf_counter()))
        self._feeder.join(left())
        self._pump.join(left())
        if self._proc.is_alive():
            self._proc.join(left() if deadline is not None else _JOIN_GRACE)

    @property
    def alive(self) -> bool:
        return self._started and self._pump.is_alive()

    @property
    def pending(self) -> int:
        """Items shipped to the child and not yet consumed there."""
        return max(0, self._shipped - int(self.status["consumed"]))

    @property
    def active(self) -> bool:
        return (self._feeding or self.pending > 0
                or bool(self.status["has_work"]))

    def load(self) -> int:
        return (self.inbox.qsize() + self.pending
                + int(self.status["queue_depth"])
                + (1 if self.status["has_work"] else 0))

    # -- producer side -----------------------------------------------------
    def submit(self, item: StageInput,
               timeout: Optional[float] = None) -> bool:
        """Bounded put, same semantics as ``StageWorker.submit``; a
        failed or finalized replica reports unavailable immediately."""
        if self.failed or self.error is not None or self._finalized:
            return False
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        while True:
            try:
                self.inbox.put(item, timeout=0.05)
                self.metrics.note_depth(self.inbox.qsize())
                return True
            except queue.Full:
                if (self._stop.is_set() or self.failed
                        or self.error is not None
                        or (self._started and not self._pump.is_alive())):
                    return False
                if deadline is not None and time.perf_counter() > deadline:
                    return False

    # -- feeder thread (parent-side admission + shipping) ------------------
    def _feed(self) -> None:
        while True:
            if self._gone.is_set():
                break
            try:
                item = self.inbox.get(timeout=self._IDLE_WAIT)
            except queue.Empty:
                if self._stop.is_set():
                    break
                continue
            if item is None:
                continue
            if self.failed or self._gone.is_set():
                self._strand([item])
                continue
            if self._stop.is_set() and not self._drain_on_stop:
                if item.cleanup is not None:
                    try:
                        item.cleanup()
                    except Exception:        # noqa: BLE001 — best effort
                        pass
                continue
            self._feeding = True
            try:
                self._ship(item)
            finally:
                self._feeding = False
        if not self.failed and self.error is None:
            try:
                self._cmd.put(("stop", self._drain_on_stop))
            except Exception:                # noqa: BLE001 — child gone
                pass

    def _ship(self, item: StageInput) -> None:
        """Parent half of ``StageWorker._admit``: FIFO assertion, lazy
        resolve (connector recv + edge transfer stay in the parent, where
        the connectors live), then segment + manifest to the child."""
        req = item.request
        if item.seq is not None:
            last = self._last_seq.get(req.req_id)
            if last is not None and item.seq <= last:
                delay = time.perf_counter() - item.t_submit
                self.metrics.note_admit(delay)
                req.note_queue_delay(self.name, delay)
                self.metrics.note_order_violation()
                self.emit(self.name, StageEvent(
                    req.req_id, "error",
                    {"error": f"{item.origin}: out-of-order chunk "
                              f"seq={item.seq} after {last}"},
                    stage=self.name))
                return
            if item.seq_last:
                self._last_seq.pop(req.req_id, None)
            else:
                self._last_seq[req.req_id] = item.seq
        self.metrics.note_active()
        try:
            inputs = item.inputs
            if item.resolve is not None:
                inputs = item.resolve()
        except Exception as e:               # noqa: BLE001 — fault isolation
            delay = time.perf_counter() - item.t_submit
            self.metrics.note_admit(delay)
            req.note_queue_delay(self.name, delay)
            self.metrics.note_error()
            self.emit(self.name, StageEvent(
                req.req_id, "error",
                {"error": f"{item.origin}: {type(e).__name__}: {e}"},
                stage=self.name))
            return
        if inputs is None:                   # transfer fn filtered this event
            delay = time.perf_counter() - item.t_submit
            self.metrics.note_admit(delay)
            req.note_queue_delay(self.name, delay)
            self.metrics.note_filtered()
            return
        req.mark_stage_start(self.name)
        # the child-side queue is the bounded half of the inbox: wait for
        # ship credit so backpressure still propagates through submit()
        while self.pending >= self.capacity:
            if self.failed or self._gone.is_set():
                self._strand([self._readmit_item(item, inputs)])
                return
            if self._stop.is_set() and not self._drain_on_stop:
                return
            time.sleep(0.001)
        item_id = self._next_item
        self._next_item += 1
        seg, manifest = shm_transport.write_segment(
            {"inputs": inputs, "data": req.data})
        if seg is not None:
            seg.close()                      # child unlinks after reading
        entry = self._readmit_item(item, inputs)
        with self._ledger_lock:
            self._ledger[item_id] = (entry, manifest)
        self._shipped += 1
        try:
            self._cmd.put(("item", item_id, req.req_id, item.origin,
                           _pack_sampling(item.sampling), item.t_submit,
                           manifest))
        except Exception:                    # noqa: BLE001 — child gone
            self._shipped -= 1
            with self._ledger_lock:
                self._ledger.pop(item_id, None)
            shm_transport.release_manifest(manifest)
            self._strand([entry])

    @staticmethod
    def _readmit_item(item: StageInput, inputs: Dict[str, Any]) -> StageInput:
        """Re-admittable copy: resolved inputs, no consumed-once
        resolve/cleanup closures, original timing and ordering marks."""
        return StageInput(
            request=item.request, sampling=item.sampling, inputs=inputs,
            origin=item.origin, affinity_hints=item.affinity_hints,
            seq=item.seq, seq_last=item.seq_last, t_submit=item.t_submit)

    # -- pump thread (child messages, death detection) ---------------------
    def _pump_loop(self) -> None:
        while True:
            try:
                msg = self._evt.get(timeout=0.05)
            except queue.Empty:
                msg = None
            except Exception:                # noqa: BLE001 — pipe torn down
                self._on_death("control channel broke")
                return
            now = time.perf_counter()
            if msg is not None:
                self._last_msg = now
                if self._dispatch(msg):      # "bye": clean child exit
                    break
                continue
            if not self._proc.is_alive():
                if self._drain_residue():
                    break
                self._on_death("process exited"
                               if self.error is None else "engine error")
                return
            limit = (self.heartbeat_timeout if self._ready.is_set()
                     else self.ready_timeout)
            if now - self._last_msg > limit:
                try:
                    self._proc.kill()
                except Exception:            # noqa: BLE001 — already gone
                    pass
                self._on_death(f"unresponsive (no heartbeat in {limit}s)")
                return
        self._finalize()

    def _drain_residue(self) -> bool:
        """Child exited: flush whatever it managed to enqueue.  Returns
        True if a clean ``bye`` was among the residue."""
        saw_bye = False
        empties = 0
        while empties < 3:
            try:
                msg = self._evt.get(timeout=0.05)
            except queue.Empty:
                empties += 1
                continue
            except Exception:                # noqa: BLE001 — pipe torn down
                break
            empties = 0
            saw_bye = self._dispatch(msg) or saw_bye
        return saw_bye

    def _dispatch(self, msg: tuple) -> bool:
        kind = msg[0]
        if kind in ("ready", "hb", "bye"):
            st = msg[1]
            d = st.get("steps", 0) - self.status.get("steps", 0)
            self.metrics.note_steps(d if d > 0 else 0)
            self.status = st
            if kind == "ready":
                self._ready.set()
            return kind == "bye"
        if kind == "admit":
            _, item_id, req_id, delay = msg
            self.metrics.note_admit(delay)
            self.metrics.note_active()
            with self._ledger_lock:
                entry = self._ledger.get(item_id)
            if entry is not None:
                entry[0].request.note_queue_delay(self.name, delay)
            return False
        if kind == "ev":
            ev = msg[1]
            ev.stage = ev.stage or self.name
            self.metrics.note_active()
            self.metrics.note_event(ev)
            if ev.kind in ("finished", "error") or (
                    ev.kind == "chunk" and ev.is_last):
                self._drop_ledger(ev.req_id)
            self.emit(self.name, ev)
            return False
        if kind == "aerr":                   # child-side admission failure
            ev = msg[1]
            self.metrics.note_error()
            self._drop_ledger(ev.req_id)
            self.emit(self.name, ev)
            return False
        if kind in ("seeded", "snap"):
            self._rpc_replies.put(msg)
            return False
        if kind == "err":
            self.error = msg[1]
            return False
        return False

    def _drop_ledger(self, req_id: int) -> None:
        with self._ledger_lock:
            done = [i for i, (it, _) in self._ledger.items()
                    if it.request.req_id == req_id]
            entries = [self._ledger.pop(i) for i in done]
        for _, manifest in entries:
            # consumed items already unlinked their segment; idempotent
            shm_transport.release_manifest(manifest)

    def _on_death(self, reason: str) -> None:
        """Replica died or wedged: reclaim every in-flight item and hand
        the set to ``on_failure`` for re-admission elsewhere."""
        if self._finalized:
            return
        self._finalized = True
        self.failed = True
        self.failure_reason = reason
        self._gone.set()
        self._stop.set()
        try:
            self.inbox.put_nowait(None)
        except queue.Full:
            pass
        with self._ledger_lock:
            entries = list(self._ledger.values())
            self._ledger.clear()
        for _, manifest in entries:
            shm_transport.release_manifest(manifest)
        items = [it for it, _ in entries]
        while True:                          # plus the un-shipped backlog
            try:
                it = self.inbox.get_nowait()
            except queue.Empty:
                break
            if it is not None:
                items.append(it)
        if self.error is not None:
            # engine crash: thread parity — surface via .error, fail the
            # stranded requests cleanly instead of re-running them on a
            # sibling (the same inputs would likely kill it too)
            for it in items:
                self.metrics.note_error()
                self.emit(self.name, StageEvent(
                    it.request.req_id, "error",
                    {"error": f"{self.label}: {reason}"}, stage=self.name))
        else:
            self.metrics.note_replica_failure()
            self._strand(items)

    def _strand(self, items: List[StageInput]) -> None:
        if not items:
            return
        cb = self.on_failure
        if cb is not None:
            try:
                cb(self, list(items))
                return
            except Exception:                # noqa: BLE001 — last resort
                pass
        for it in items:
            self.metrics.note_error()
            self.emit(self.name, StageEvent(
                it.request.req_id, "error",
                {"error": f"{self.label}: replica died "
                          f"({self.failure_reason or 'gone'})"},
                stage=self.name))

    def _finalize(self) -> None:
        if self._finalized:
            return
        self._finalized = True
        self._gone.set()
        with self._ledger_lock:
            entries = list(self._ledger.values())
            self._ledger.clear()
        for _, manifest in entries:
            shm_transport.release_manifest(manifest)
        self._proc.join(timeout=_JOIN_GRACE)
        if self._proc.is_alive():            # pragma: no cover — stuck exit
            self._proc.kill()

    # -- RPCs (seed / snapshot over the control queues) --------------------
    def _rpc(self, msg: tuple, expect: str,
             timeout: float = 60.0) -> Optional[Any]:
        if not self._ready.wait(timeout=timeout):
            return None
        with self._rpc_lock:
            if self._gone.is_set() or self.failed or self.error is not None:
                return None
            while True:                      # drop stale replies
                try:
                    self._rpc_replies.get_nowait()
                except queue.Empty:
                    break
            try:
                self._cmd.put(msg)
            except Exception:                # noqa: BLE001 — child gone
                return None
            deadline = time.perf_counter() + timeout
            while time.perf_counter() < deadline:
                if self._gone.is_set():
                    return None
                try:
                    kind, val = self._rpc_replies.get(timeout=0.05)
                except queue.Empty:
                    continue
                if kind == expect:
                    return val
            return None

    def prefix_snapshot(self, max_pages: int = 64,
                        timeout: float = 60.0) -> Optional[list]:
        """Donor side of warm seeding: child snapshot, shipped back
        through its own segment."""
        manifest = self._rpc(("snapshot", max_pages), "snap",
                             timeout=timeout)
        if manifest is None:
            return None
        try:
            return shm_transport.read_and_release(manifest).get("paths")
        except Exception:                    # noqa: BLE001 — advisory
            return None

    def seed_snapshot(self, snapshot: Any,
                      timeout: float = 60.0) -> Optional[int]:
        """Receiver side: ship a parent-held snapshot into the child's
        prefix index (ownership of the segment passes to the child)."""
        try:
            seg, manifest = shm_transport.write_segment({"paths": snapshot})
        except Exception:                    # noqa: BLE001 — advisory
            return None
        if seg is not None:
            seg.close()
        n = self._rpc(("seed", manifest, True), "seeded", timeout=timeout)
        if n is None:
            shm_transport.release_manifest(manifest)
        return n

    def seed_manifest(self, manifest: Any,
                      timeout: float = 60.0) -> Optional[int]:
        """Seed from a connector-exported manifest; the connector keeps
        segment ownership (caller releases the key afterwards)."""
        return self._rpc(("seed", manifest, False), "seeded",
                         timeout=timeout)
