"""Metrics-driven dynamic replica scaling (paper §3.2: flexible resource
allocation at runtime).

The :class:`ScalingController` runs in its own thread and, every
``interval`` seconds, consumes one window of WorkerMetrics-derived
signals per stage:

  - ``busy``   — engine busy seconds this window / (interval × replicas):
    the fraction of the stage's replica capacity that was computing;
  - ``backlog`` — live queue depth (inboxes + admitted-but-unfinished)
    normalized per replica;
  - ``queue_delay_p95`` — p95 of the queue delays observed this window
    (logged with every decision for the stage report).

``pressure = busy + min(backlog / backlog_norm, backlog_cap)`` ranks the
stages.  When the hottest stage's pressure exceeds ``hi`` the controller
adds it a replica — from free budget headroom if any, otherwise by
*moving* one from the coldest stage whose pressure is under ``lo`` and
which has replicas to spare (``scale_down(drain=True)`` first, so no
in-flight request is lost, then ``scale_up`` on the bottleneck).  A
cooldown of ``cooldown`` windows follows every action so a move's effect
is observed before the next one.

Every action is appended to ``actions`` (kind, stage, donor, pressures,
wall time) — benchmarks and tests assert on that trace.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass
class ScalingConfig:
    interval: float = 0.25        # seconds between decision windows
    replica_budget: Optional[int] = None   # None: current total replicas
    min_replicas: int = 1         # floor per stage
    hi: float = 0.75              # pressure above which a stage is hot
    lo: float = 0.40              # pressure below which a stage can donate
    cooldown: int = 2             # windows to hold after an action
    backlog_norm: float = 8.0     # per-replica depth that counts as 1.0
    backlog_cap: float = 2.0      # backlog contribution ceiling


@dataclass
class StageWindow:
    """One decision window's signals for one stage."""
    replicas: int
    busy: float                   # busy fraction of replica capacity
    backlog: float                # live queue depth (absolute)
    queue_delay_p95: float        # p95 of delays observed this window
    pressure: float = field(init=False)

    def __post_init__(self) -> None:
        pass                      # pressure set by the controller


class ScalingController:
    """Moves replicas between stages under a global replica budget."""

    def __init__(self, orch: Any, config: Optional[ScalingConfig] = None):
        self.orch = orch
        self.cfg = config or ScalingConfig()
        # the controller thread appends; benchmarks and tests read the
        # trace live — take a copy via action_log() while serving
        self._lock = threading.Lock()
        self.actions: List[Dict[str, Any]] = []   # guarded-by: _lock
        self.windows = 0
        self._prev_busy: Dict[str, float] = {}
        self._prev_delay_len: Dict[str, Dict[int, int]] = {}
        self._prev_t: Optional[float] = None
        self._cooldown = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        orch._scaler = self          # orch.shutdown() stops us first

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "ScalingController":
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop,
                                            name="scaling-controller",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def action_log(self) -> List[Dict[str, Any]]:
        """Copy of the decision trace, safe to read while serving."""
        with self._lock:
            return list(self.actions)

    def _loop(self) -> None:
        while not self._stop.wait(self.cfg.interval):
            if not getattr(self.orch, "_started", False):
                continue              # backend not serving yet
            try:
                self.tick()
            except Exception:         # noqa: BLE001 — advisory subsystem:
                pass                  # never kill serving over a scale step

    # -- one decision window ----------------------------------------------
    def _measure(self) -> Dict[str, StageWindow]:
        now = time.perf_counter()
        dt = (now - self._prev_t) if self._prev_t is not None \
            else self.cfg.interval
        self._prev_t = now
        out: Dict[str, StageWindow] = {}
        for name in self.orch.graph.stages:
            rs = self.orch._workers.get(name)
            if rs is None:
                continue
            n = max(rs.n_replicas, 1)
            busy_now = sum(getattr(e, "busy_time", 0.0) for e in rs.engines)
            busy_d = max(0.0, busy_now - self._prev_busy.get(name, busy_now))
            self._prev_busy[name] = busy_now
            # windowed queue-delay p95: only the samples added since the
            # previous window (per replica-id, so scale events don't skew)
            seen = self._prev_delay_len.setdefault(name, {})
            fresh: List[float] = []
            for rid, metrics in self.orch._stage_metrics[name].items():
                raw = metrics.raw_delays()
                fresh.extend(raw[seen.get(rid, 0):])
                seen[rid] = len(raw)
            qd95 = (float(np.percentile(np.asarray(fresh), 95))
                    if fresh else 0.0)
            win = StageWindow(replicas=n,
                              busy=busy_d / (dt * n) if dt > 0 else 0.0,
                              backlog=float(rs.queue_depth()),
                              queue_delay_p95=qd95)
            win.pressure = win.busy + min(
                win.backlog / (self.cfg.backlog_norm * n),
                self.cfg.backlog_cap)
            out[name] = win
        return out

    def tick(self) -> Optional[Dict[str, Any]]:
        """One decision window; returns the action taken, if any."""
        wins = self._measure()
        self.windows += 1
        if not wins:
            return None
        if self.windows == 1:
            # priming window: busy deltas are zero by construction, so
            # pressure is pure backlog — a submit burst that hasn't been
            # processed yet is not evidence of a bottleneck.  Never act on
            # the first measurement.
            return None
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        cfg = self.cfg
        total = sum(w.replicas for w in wins.values())
        budget = cfg.replica_budget if cfg.replica_budget is not None \
            else total
        hot_name = max(wins, key=lambda n: wins[n].pressure)
        hot = wins[hot_name]
        if hot.pressure <= cfg.hi:
            return None
        if self.orch.engine_factories.get(hot_name) is None:
            return None           # can't build replicas for this stage
        action: Optional[Dict[str, Any]] = None
        rs = self.orch._workers.get(hot_name)
        n_seeds = len(getattr(rs, "seed_events", ())) if rs else 0
        if total < budget and self.orch.scale_up(hot_name):
            action = {"kind": "add", "stage": hot_name}
        else:
            donors = [n for n, w in wins.items()
                      if n != hot_name and w.replicas > cfg.min_replicas
                      and w.pressure < cfg.lo]
            if donors:
                donor = min(donors, key=lambda n: wins[n].pressure)
                # drain the donor's replica fully (loses nothing), then
                # hand its slot to the bottleneck stage
                if self.orch.scale_down(donor, drain=True) \
                        and self.orch.scale_up(hot_name):
                    action = {"kind": "move", "stage": hot_name,
                              "donor": donor,
                              "donor_pressure": wins[donor].pressure}
        if action is not None:
            if rs is not None and len(rs.seed_events) > n_seeds:
                # the scale_up above warm-seeded the new replica's prefix
                # cache from a sibling — record it with the decision
                action["warm_seed"] = dict(rs.seed_events[-1])
            action.update({
                "t": time.perf_counter(),
                "pressure": hot.pressure,
                "busy": hot.busy,
                "backlog": hot.backlog,
                "queue_delay_p95": hot.queue_delay_p95,
                "replicas": self.orch.replica_counts(),
            })
            with self._lock:
                self.actions.append(action)
            self._cooldown = cfg.cooldown
        return action
