"""Training launcher: train a reduced (smoke) variant of any assigned
architecture on the synthetic pipeline, with checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
      --steps 200 --batch 8 --seq 64 --ckpt out/ck.npz
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.data import TokenStream
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (non-smoke) config — production only")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full_config)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(1, args.steps // 20),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    ds = iter(TokenStream(cfg, args.batch, args.seq))

    t0 = time.perf_counter()
    for i in range(1, args.steps + 1):
        b = next(ds)
        params, opt, m = step_fn(params, opt, jnp.asarray(b["inputs"]),
                                 jnp.asarray(b["labels"]))
        if i % 10 == 0 or i == 1:
            print(f"step {i:5d}  loss {float(m['loss']):.4f}  "
                  f"ce {float(m['ce']):.4f}  gnorm {float(m['grad_norm']):.3f}"
                  f"  lr {float(m['lr']):.2e}  "
                  f"({i/(time.perf_counter()-t0):.2f} it/s)")
        if args.ckpt and i % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, params, opt, step=i)
            print(f"checkpointed -> {args.ckpt}")
    if args.ckpt:
        checkpoint.save(args.ckpt, params, opt, step=args.steps)


if __name__ == "__main__":
    main()
