"""Production-scale PIPELINE dry-run: the paper's actual deployment case.

A Qwen3-Omni-like pipeline at full scale, with the paper's per-stage
accelerator allocation (Fig 3(c)) mapped to submeshes of one 16x16 pod:

  - Thinker  = qwen3-moe-30b-a3b (the assigned arch)   -> 16x8 submesh
  - Talker   = ~2B dense AR                            -> 16x4 submesh
  - Vocoder  = 24L DiT                                  -> 16x4 submesh

Each stage's serve step is lowered + compiled on ITS OWN submesh —
proving the disaggregated resource split is coherent at production scale.

  PYTHONPATH=src python -m repro.launch.dryrun_pipeline
"""
# Must precede any jax import (device count locks on first init).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, get_config
from repro.launch.dryrun import _sds, collective_bytes
from repro.launch.mesh import make_production_mesh, make_stage_submesh
from repro.models import transformer as T
from repro.models.dit import DiTConfig, dit_forward, init_dit
from repro.sharding import specs as S

TALKER_CFG = ModelConfig(
    name="qwen3-omni-talker-2b", arch_type="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=5632, vocab_size=8192,   # codec vocabulary
    source="Qwen3-Omni technical report (talker, approx.)",
)

VOCODER_CFG = DiTConfig(
    name="qwen-omni-vocoder-dit", num_layers=24, d_model=1024, num_heads=16,
    d_ff=4096, in_dim=128, cond_dim=2048, num_steps=20, dtype="bfloat16")


def _lower_stage(name, fn, args, mesh):
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    rec = {"stage": name, "devices": int(mesh.devices.size),
           "compile_s": round(time.time() - t0, 2)}
    try:
        ma = compiled.memory_analysis()
        rec["args_gb_dev"] = round(ma.argument_size_in_bytes / 1e9, 3)
        rec["temp_gb_dev"] = round(ma.temp_size_in_bytes / 1e9, 3)
    except Exception:
        pass
    rec["collective_bytes"] = collective_bytes(compiled.as_text()).get(
        "total", 0)
    return rec


def main() -> None:
    mesh = make_production_mesh()                 # 16 x 16
    thinker_mesh = make_stage_submesh(mesh, "model", 0, 8)    # 128 chips
    talker_mesh = make_stage_submesh(mesh, "model", 8, 12)    # 64 chips
    vocoder_mesh = make_stage_submesh(mesh, "model", 12, 16)  # 64 chips
    B, CACHE = 64, 8192
    results = []

    # ---- Thinker: qwen3-moe-30b decode on 16x8 -------------------------
    cfg = get_config("qwen3_moe_30b_a3b")
    params_tpl = jax.eval_shape(lambda: T.init_params(cfg,
                                                      jax.random.PRNGKey(0)))
    pspecs = S.param_specs(cfg, params_tpl, thinker_mesh)
    params_sds = _sds(params_tpl, thinker_mesh, pspecs)
    cache_tpl = jax.eval_shape(lambda: T.init_decode_cache(cfg, B, CACHE))
    cspecs = S.kv_cache_specs(cfg, thinker_mesh, B)
    cache_sds = _sds(cache_tpl, thinker_mesh,
                     {k: cspecs[k] for k in cache_tpl})
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=NamedSharding(
        thinker_mesh, P("data", None)))

    def thinker_step(params, cache, tokens):
        pos = jnp.full((B,), CACHE - 1, jnp.int32)
        return T.forward_decode(cfg, params, cache, tokens, pos)
    results.append(_lower_stage("thinker(qwen3-moe-30b, 16x8)", thinker_step,
                                (params_sds, cache_sds, tok), thinker_mesh))

    # ---- Talker: 2B dense decode on 16x4 --------------------------------
    tcfg = TALKER_CFG
    tparams_tpl = jax.eval_shape(lambda: T.init_params(tcfg,
                                                       jax.random.PRNGKey(1)))
    tspecs = S.param_specs(tcfg, tparams_tpl, talker_mesh)
    tparams_sds = _sds(tparams_tpl, talker_mesh, tspecs)
    tcache_tpl = jax.eval_shape(lambda: T.init_decode_cache(tcfg, B, CACHE))
    tcspecs = S.kv_cache_specs(tcfg, talker_mesh, B)
    tcache_sds = _sds(tcache_tpl, talker_mesh,
                      {k: tcspecs[k] for k in tcache_tpl})
    ttok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=NamedSharding(
        talker_mesh, P("data", None)))

    def talker_step(params, cache, tokens):
        pos = jnp.full((B,), CACHE - 1, jnp.int32)
        return T.forward_decode(tcfg, params, cache, tokens, pos)
    results.append(_lower_stage("talker(2B, 16x4)", talker_step,
                                (tparams_sds, tcache_sds, ttok),
                                talker_mesh))

    # ---- Vocoder: DiT denoise step on 16x4 -------------------------------
    vcfg = VOCODER_CFG
    vparams_tpl = jax.eval_shape(lambda: init_dit(vcfg,
                                                  jax.random.PRNGKey(2)))
    vspecs = S.param_specs(cfg, vparams_tpl, vocoder_mesh)  # same rule names
    vparams_sds = _sds(vparams_tpl, vocoder_mesh, vspecs)
    x_t = jax.ShapeDtypeStruct((B, 512, vcfg.in_dim), jnp.bfloat16,
                               sharding=NamedSharding(vocoder_mesh,
                                                      P("data", None, None)))
    cond = jax.ShapeDtypeStruct((B, 256, vcfg.cond_dim), jnp.bfloat16,
                                sharding=NamedSharding(
                                    vocoder_mesh, P("data", None, None)))
    tvec = jax.ShapeDtypeStruct((B,), jnp.float32, sharding=NamedSharding(
        vocoder_mesh, P("data")))

    def vocoder_step(params, x_t, t, cond):
        return dit_forward(vcfg, params, x_t, t, cond)
    results.append(_lower_stage("vocoder(DiT-24L, 16x4)", vocoder_step,
                                (vparams_sds, x_t, tvec, cond),
                                vocoder_mesh))

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/pipeline_dryrun.json", "w") as f:
        json.dump(results, f, indent=1)
    for r in results:
        print(json.dumps(r))


if __name__ == "__main__":
    main()
