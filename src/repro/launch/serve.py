"""Serving launcher.

Three modes:
  - pipeline (offline): serve an any-to-any stage-graph pipeline through
    the per-stage-worker backend, batch-submitted at t=0
      PYTHONPATH=src python -m repro.launch.serve --pipeline qwen_omni \
          --requests 8 --max-batch 4
  - pipeline --online: Poisson arrivals + admission control + streaming
    result consumption — each stage batches independently in its own
    worker thread while the front-end keeps admitting
      PYTHONPATH=src python -m repro.launch.serve --pipeline qwen_omni \
          --online --requests 16 --rate 4.0 --max-inflight 8
  - single: serve one assigned architecture (smoke-scale) as a 1-stage graph
      PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
          --requests 4
"""
from __future__ import annotations

import argparse
import queue
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.configs.pipelines import _kv, build_ar_dit, build_mimo_audio, \
    build_qwen_omni
from repro.core.config import ServeConfig
from repro.core.graph import StageGraph
from repro.core.metrics import stage_report, summarize, summarize_queueing
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def build_single_arch(arch: str, max_batch: int, max_new: int, seed: int = 0,
                      prefix_cache: bool = False):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))

    def make_engine():
        return AREngine(
            arch, cfg, params, kv=_kv(max_batch), max_batch=max_batch,
            enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=max_new,
                                            temperature=0.8, top_k=20))

    graph = StageGraph()
    graph.add_stage(StageSpec(arch, "ar", is_output=True))
    return graph, {arch: make_engine()}, {
        "cfg": cfg, "engine_factories": {arch: make_engine}}


def _make_inputs(pipeline, rng):
    if pipeline == "mimo_audio":
        return {"audio": rng.standard_normal((32, 16)).astype(np.float32)}
    return {"tokens": rng.integers(0, 200, size=int(
        rng.integers(6, 24))).astype(np.int32)}


def serve_online(orch: Orchestrator, pipeline, *, n_requests: int,
                 rate_hz: float, max_inflight: int, seed: int = 0,
                 time_limit: float = 300.0, verbose: bool = True):
    """Online front-end: Poisson arrivals, admission control (at most
    ``max_inflight`` requests in the backend; later arrivals wait in the
    admission queue), streaming consumption of completions as they finish.

    Request.arrival_time is stamped at the Poisson arrival instant, so JCT
    and TTFT include any admission-control wait.
    """
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate_hz, 1e-9),
                                         size=n_requests))
    inputs = [_make_inputs(pipeline, rng) for _ in range(n_requests)]

    orch.start()
    t0 = time.perf_counter()
    reqs, admission_q = [], []
    submitted = done = i = 0
    while done < n_requests:
        now = time.perf_counter() - t0
        while i < n_requests and arrivals[i] <= now:
            reqs.append(Request(inputs=inputs[i]))     # arrival stamp = now
            admission_q.append(reqs[-1])
            i += 1
        # admission control: bound the work resident in the backend
        while admission_q and submitted - done < max_inflight:
            orch.submit(admission_q.pop(0))
            submitted += 1
        try:                                   # streaming result consumption
            r = orch.completions.get(timeout=0.005)
            done += 1
            if verbose:
                state = "FAILED " + r.failed if r.failed else "ok"
                ttft = (r.first_output_time - r.arrival_time
                        if r.first_output_time else float("nan"))
                print(f"  req {r.req_id}: jct={r.jct:.3f}s ttft={ttft:.3f}s "
                      f"[{state}]")
        except queue.Empty:
            pass
        if orch.worker_error:                  # fail fast on a dead stage
            print(f"stage worker died: {orch.worker_error} "
                  f"({done}/{n_requests} served)")
            break
        if time.perf_counter() - t0 > time_limit:
            print(f"time limit {time_limit}s hit with {done}/{n_requests}")
            break
    wall = time.perf_counter() - t0
    # nothing is in flight on the normal exit; on the abnormal exits we
    # must NOT block draining a backlog past the measurement window
    orch.shutdown(drain=False)
    return reqs, wall


_EPILOG = """\
serving configuration (ServeConfig):
  Every flag below the line funnels through ServeConfig.from_args into
  one typed, validated config object — the same API library callers use:

      from repro.core.config import ServeConfig, StageConfig, EngineSpec
      config = ServeConfig(
          backend="threaded", routing="affinity", queue_capacity=64,
          stages={"decode": StageConfig(
              replicas=2, isolation="process",
              engine_spec=EngineSpec(
                  "repro.configs.pipelines:build_stage_engine",
                  {"pipeline": "pd", "stage": "decode"}))})
      orch = Orchestrator(graph, engines, config=config)

  isolation="process" serves a stage from spawned OS processes: request
  tensors travel through named shared-memory segments, a dead replica is
  detected by heartbeat and its in-flight requests re-admitted to the
  survivors.  See examples/process_isolation.py.

examples:
  # 2 talker replicas, affinity routing
  python -m repro.launch.serve --pipeline qwen_omni --requests 16 \\
      --replicas talker=2

  # decode stage in its own process, 5s recv timeout
  python -m repro.launch.serve --pipeline pd --requests 8 \\
      --isolation decode=process --recv-timeout 5
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog=_EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--pipeline", default=None,
                    choices=[None, "qwen_omni", "qwen3_omni", "glm_image",
                             "mimo_audio", "pd"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="threaded",
                    choices=["threaded", "sync"],
                    help="threaded = per-stage workers (default); "
                         "sync = lock-step ablation baseline")
    ap.add_argument("--online", action="store_true",
                    help="Poisson arrivals + admission control + streaming "
                         "result consumption (threaded backend only)")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="--online arrival rate (req/s)")
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="--online admission control limit")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="block-level KV prefix caching on every AR stage "
                         "(default on)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false")
    ap.add_argument("--replicas", default=None, metavar="STAGE=N[,STAGE=N]",
                    help="serve a stage with N engine replicas, e.g. "
                         "--replicas talker=2,vocoder=2 (threaded backend; "
                         "stages need an engine factory, which every "
                         "built-in pipeline provides)")
    ap.add_argument("--routing", default="affinity",
                    choices=["round_robin", "least_loaded", "affinity"],
                    help="replica routing policy: round_robin cycles; "
                         "least_loaded picks the emptiest; affinity "
                         "(default) routes to the replica holding the "
                         "longest cached KV prefix, falling back to "
                         "least-loaded")
    ap.add_argument("--isolation", default=None,
                    metavar="STAGE=MODE[,..]|MODE",
                    help="replica isolation per stage (thread|process), "
                         "e.g. --isolation decode=process; a bare mode "
                         "applies to every stage. process replicas run "
                         "in spawned workers with shared-memory tensor "
                         "transport (threaded backend only)")
    ap.add_argument("--queue-capacity", dest="queue_capacity", type=int,
                    default=64,
                    help="bounded per-stage worker inbox (backpressure)")
    ap.add_argument("--recv-timeout", dest="recv_timeout", type=float,
                    default=60.0,
                    help="connector receive timeout in seconds; on expiry "
                         "the request fails with a TransferTimeout naming "
                         "the key and edge")
    ap.add_argument("--no-warm-seed", dest="warm_seed",
                    action="store_false", default=True,
                    help="disable warm-seeding scaled-up replicas from "
                         "the warmest sibling's prefix snapshot")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the ScalingController: move replicas to the "
                         "bottleneck stage at runtime from WorkerMetrics "
                         "(busy fraction + backlog pressure)")
    ap.add_argument("--replica-budget", type=int, default=None,
                    help="--autoscale global replica budget (default: the "
                         "total launched replicas; extra headroom lets the "
                         "controller ADD replicas instead of moving them)")
    ap.add_argument("--scale-interval", type=float, default=0.25,
                    help="--autoscale decision window in seconds")
    args = ap.parse_args()

    if args.replicas and args.backend != "threaded":
        ap.error("--replicas requires --backend threaded")
    if args.isolation and args.backend != "threaded":
        ap.error("--isolation requires --backend threaded")

    if args.pipeline == "qwen_omni":
        graph, engines, bundle = build_qwen_omni(
            max_batch=args.max_batch, prefix_cache=args.prefix_cache)
    elif args.pipeline == "qwen3_omni":
        graph, engines, bundle = build_qwen_omni(
            max_batch=args.max_batch, vocoder_kind="cnn",
            prefix_cache=args.prefix_cache)
    elif args.pipeline == "glm_image":
        graph, engines, bundle = build_ar_dit(
            "glm_image", max_batch=args.max_batch,
            prefix_cache=args.prefix_cache)
    elif args.pipeline == "mimo_audio":
        graph, engines, bundle = build_mimo_audio(
            max_batch=args.max_batch, prefix_cache=args.prefix_cache)
    elif args.pipeline == "pd":
        from repro.configs.pipelines import build_pd_disaggregated
        graph, engines, bundle = build_pd_disaggregated(
            max_batch=args.max_batch, max_new=args.max_new,
            prefix_cache=args.prefix_cache)
    elif args.arch:
        graph, engines, bundle = build_single_arch(
            args.arch, args.max_batch, args.max_new, args.seed,
            prefix_cache=args.prefix_cache)
    else:
        ap.error("pass --pipeline or --arch")

    try:
        config = ServeConfig.from_args(
            args, engine_factories=bundle.get("engine_factories"),
            engine_specs=bundle.get("engine_specs"))
        orch = Orchestrator(graph, engines, config=config)
    except ValueError as e:
        ap.error(str(e))
    scaler = None
    if args.autoscale:
        from repro.core.scaling import ScalingConfig, ScalingController
        if args.backend != "threaded":
            ap.error("--autoscale requires --backend threaded")
        scaler = ScalingController(orch, ScalingConfig(
            interval=args.scale_interval,
            replica_budget=args.replica_budget)).start()
    rng = np.random.default_rng(args.seed)

    if args.online:
        if args.backend != "threaded":
            ap.error("--online requires --backend threaded")
        reqs, wall = serve_online(
            orch, args.pipeline, n_requests=args.requests,
            rate_hz=args.rate, max_inflight=args.max_inflight,
            seed=args.seed)
    else:
        t0 = time.perf_counter()
        if args.backend == "threaded":
            orch.start()          # admissions route through stage workers
        reqs = []
        for _ in range(args.requests):
            reqs.append(Request(inputs=_make_inputs(args.pipeline, rng)))
            orch.submit(reqs[-1])
        orch.run()
        wall = time.perf_counter() - t0

    m = summarize(reqs, wall_time=wall)
    done = [r for r in reqs if r.completion_time is not None]
    print(f"completed {len(done)}/{args.requests} requests "
          f"in {wall:.2f}s  ({m['req_per_s']:.2f} req/s)  "
          f"backend={args.backend}")
    print(f"JCT p50={m['jct_p50']:.3f}s p95={m['jct_p95']:.3f}s  "
          f"TTFT p50={m['ttft_p50']:.3f}s")
    if args.backend == "threaded":
        print(stage_report(orch.stage_metrics()))
        qd = summarize_queueing(reqs)
        if qd:
            print("per-request queueing delay:",
                  {k: f"p95={v['p95']*1e3:.2f}ms" for k, v in qd.items()})
        if args.replicas or args.isolation or args.autoscale:
            print("replicas:", orch.replica_counts(),
                  f"routing={args.routing}")
        if scaler is not None:
            print(f"autoscale: {scaler.windows} windows, "
                  f"{len(scaler.actions)} action(s)")
            for a in scaler.actions:
                src = f" from {a['donor']}" if "donor" in a else ""
                seed = (f" warm-seeded {a['warm_seed']['pages']} pages"
                        if "warm_seed" in a else "")
                print(f"  {a['kind']} -> {a['stage']}{src} "
                      f"(pressure={a['pressure']:.2f} "
                      f"busy={a['busy']:.2f} backlog={a['backlog']:.0f}) "
                      f"replicas={a['replicas']}{seed}")
    else:
        print("stage busy:", {k: round(v, 3)
                              for k, v in orch.stage_busy_times().items()})
    for kind, st in orch.connector_stats().items():
        print(f"connector[{kind}]: {st.calls} transfers, {st.bytes} bytes, "
              f"{st.wall_time*1e3:.2f} ms wall")
    for name in graph.stages:
        ps: dict = {}
        for eng in orch.stage_replicas[name]:       # summed over replicas
            for k, v in (getattr(eng, "prefix_stats", None) or {}).items():
                ps[k] = ps.get(k, 0) + v
        if ps.get("lookups"):
            tot = ps["cached_tokens"] + ps["computed_tokens"]
            rate = 100.0 * ps["cached_tokens"] / tot if tot else 0.0
            print(f"prefix-cache[{name}]: hits={ps['hits']}/"
                  f"{ps['lookups']} cached={ps['cached_tokens']} "
                  f"(full-block {ps.get('full_block_tokens', 0)} + "
                  f"partial {ps.get('partial_tokens', 0)} in "
                  f"{ps.get('partial_hits', 0)} partial hits) "
                  f"computed={ps['computed_tokens']} tokens "
                  f"(hit-rate {rate:.1f}%)")


if __name__ == "__main__":
    main()
