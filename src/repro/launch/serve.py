"""Serving launcher.

Two modes:
  - pipeline: serve an any-to-any stage-graph pipeline (the paper's case)
      PYTHONPATH=src python -m repro.launch.serve --pipeline qwen_omni \
          --requests 8 --max-batch 4
  - single:   serve one assigned architecture (smoke-scale) as a 1-stage graph
      PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x7b \
          --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.configs.pipelines import _kv, build_ar_dit, build_mimo_audio, \
    build_qwen_omni
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def build_single_arch(arch: str, max_batch: int, max_new: int, seed: int = 0):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    eng = AREngine(arch, cfg, params, kv=_kv(max_batch), max_batch=max_batch,
                   default_sampling=SamplingParams(max_new_tokens=max_new,
                                                   temperature=0.8, top_k=20))
    graph = StageGraph()
    graph.add_stage(StageSpec(arch, "ar", is_output=True))
    return graph, {arch: eng}, {"cfg": cfg}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pipeline", default=None,
                    choices=[None, "qwen_omni", "qwen3_omni", "glm_image",
                             "mimo_audio", "pd"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.pipeline == "qwen_omni":
        graph, engines, _ = build_qwen_omni(max_batch=args.max_batch)
    elif args.pipeline == "qwen3_omni":
        graph, engines, _ = build_qwen_omni(max_batch=args.max_batch,
                                            vocoder_kind="cnn")
    elif args.pipeline == "glm_image":
        graph, engines, _ = build_ar_dit("glm_image",
                                         max_batch=args.max_batch)
    elif args.pipeline == "mimo_audio":
        graph, engines, _ = build_mimo_audio(max_batch=args.max_batch)
    elif args.pipeline == "pd":
        from repro.configs.pipelines import build_pd_disaggregated
        graph, engines, _ = build_pd_disaggregated(
            max_batch=args.max_batch, max_new=args.max_new)
    elif args.arch:
        graph, engines, _ = build_single_arch(args.arch, args.max_batch,
                                              args.max_new, args.seed)
    else:
        ap.error("pass --pipeline or --arch")

    orch = Orchestrator(graph, engines)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    reqs = []
    for _ in range(args.requests):
        if args.pipeline == "mimo_audio":
            inputs = {"audio": rng.standard_normal((32, 16)).astype(np.float32)}
        else:
            inputs = {"tokens": rng.integers(0, 200, size=int(
                rng.integers(6, 24))).astype(np.int32)}
        reqs.append(Request(inputs=inputs))
        orch.submit(reqs[-1])
    done = orch.run()
    wall = time.perf_counter() - t0
    from repro.core.metrics import summarize
    m = summarize(reqs, wall_time=wall)
    print(f"completed {len(done)}/{args.requests} requests "
          f"in {wall:.2f}s  ({m['req_per_s']:.2f} req/s)")
    print(f"JCT p50={m['jct_p50']:.3f}s p95={m['jct_p95']:.3f}s  "
          f"TTFT p50={m['ttft_p50']:.3f}s")
    print("stage busy:", {k: round(v, 3)
                          for k, v in orch.stage_busy_times().items()})
    for kind, st in orch.connector_stats().items():
        print(f"connector[{kind}]: {st.calls} transfers, {st.bytes} bytes, "
              f"{st.wall_time*1e3:.2f} ms wall")


if __name__ == "__main__":
    main()
