"""Production mesh construction.

Single pod: 16x16 = 256 chips (TPU v5e pod), axes ("data", "model").
Multi-pod: 2x16x16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis crosses DCN; the dry-run proves it shards.

Defined as functions so importing this module never touches jax device
state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_stage_submesh(mesh, axis: str, lo: int, hi: int):
    """Carve a stage submesh out of the global mesh along one axis
    (per-stage accelerator allocation, paper §3.3): devices [lo, hi) of
    ``axis`` become the stage's own mesh with the same axis names."""
    from jax.sharding import Mesh
    devs = mesh.devices
    idx = mesh.axis_names.index(axis)
    sl = [slice(None)] * devs.ndim
    sl[idx] = slice(lo, hi)
    return Mesh(devs[tuple(sl)], mesh.axis_names)


# TPU v5e hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
