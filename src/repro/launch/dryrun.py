"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers + compiles.

Usage (each invocation is a fresh process so the forced device count holds):
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_5_14b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Writes one JSON per combo with cost_analysis / memory_analysis / collective
byte counts parsed from the partitioned HLO — the roofline inputs.
"""
# The forced host device count MUST precede any jax import (device count is
# locked at first init). Keep these the first two lines of the module.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, ModelConfig,
                                ShapeConfig, get_config, shape_skips,
                                variant_for_shape)
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.sharding import specs as S
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step

_COLL_RE = re.compile(
    r"(\w+)\[([0-9,]*)\][^ ]* (all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-tensor bytes of collectives in the partitioned HLO,
    multiplying ops inside while-loop bodies by the loop trip count
    (XLA's own cost analysis counts loop bodies once — verified — so a
    per-computation walk with trip-count multipliers is required for
    scan-over-layers / scan-over-sequence models)."""
    # --- split into computations ---------------------------------------
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None:
            comps[cur].append(line)

    # --- per-computation collective bytes + while edges -----------------
    bytes_by_comp: dict[str, dict] = {}
    while_edges: dict[str, list] = {}            # comp -> [(cond, body)]
    trip_of_cond: dict[str, int] = {}
    for name, lines in comps.items():
        per = {}
        edges = []
        consts = []
        for line in lines:
            for m in _COLL_RE.finditer(line):
                dtype, shape, op = m.group(1), m.group(2), m.group(3)
                nb = _DTYPE_BYTES.get(dtype, 4)
                for d in shape.split(","):
                    if d:
                        nb *= int(d)
                per[op] = per.get(op, 0) + nb
            w = _WHILE_RE.search(line)
            if w:
                edges.append((w.group(1), w.group(2)))
            consts += [int(c) for c in _CONST_RE.findall(line)]
        bytes_by_comp[name] = per
        while_edges[name] = edges
        if consts:
            trip_of_cond[name] = max(consts)     # heuristic: loop bound

    # --- propagate multipliers from ENTRY --------------------------------
    mult = {name: 0.0 for name in comps}
    if entry:
        mult[entry] = 1.0
    for _ in range(len(comps)):                  # fixpoint (call DAG)
        changed = False
        for name, edges in while_edges.items():
            if mult.get(name, 0.0) <= 0:
                continue
            for cond, body in edges:
                trips = trip_of_cond.get(cond, 1)
                want = mult[name] * max(1, trips)
                if body in mult and mult[body] < want:
                    mult[body] = want
                    changed = True
        if not changed:
            break

    out = {}
    raw = {}
    for name, per in bytes_by_comp.items():
        scale = mult.get(name, 0.0)
        if scale <= 0 and per:
            scale = 1.0                          # unreached? count once
        for op, nb in per.items():
            out[op] = out.get(op, 0) + nb * scale
            out["total"] = out.get("total", 0) + nb * scale
            raw[op] = raw.get(op, 0) + nb
            raw["total"] = raw.get("total", 0) + nb
    out["uncorrected_total"] = raw.get("total", 0)
    return out


def opt_specs(params_tpl, pspecs, mesh):
    """ZeRO-ish optimizer-state sharding: additionally shard the stacked
    layer dim (or first unsharded dim divisible by the data axis) over
    "data". Beyond-paper optimization; cuts opt-state memory 16x."""
    dsize = mesh.shape["data"]

    def f(tpl, spec):
        parts = list(spec) + [None] * (tpl.ndim - len(spec))
        for i, (dim, p) in enumerate(zip(tpl.shape, parts)):
            if p is None and dim % dsize == 0 and dim > 0:
                parts[i] = "data"
                break
        return P(*parts)

    return jax.tree.map(f, params_tpl, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, Ssz = shape.global_batch, shape.seq_len
    tok_spec = S.token_specs(cfg, mesh, B)
    shard = lambda sp: NamedSharding(mesh, sp)
    if cfg.modality == "audio_frames":
        tok = jax.ShapeDtypeStruct((B, Ssz, cfg.d_model), jnp.bfloat16,
                                   sharding=shard(tok_spec))
    else:
        tok = jax.ShapeDtypeStruct((B, Ssz), jnp.int32,
                                   sharding=shard(tok_spec))
    if shape.kind == "train":
        lbl = jax.ShapeDtypeStruct((B, Ssz), jnp.int32,
                                   sharding=shard(P(*tok_spec[:2])
                                                  if len(tok_spec) > 1
                                                  else tok_spec))
        return {"inputs": tok, "labels": lbl}
    if shape.kind == "prefill":
        return {"inputs": tok}
    # decode: one token per sequence + full cache
    if cfg.modality == "audio_frames":
        one = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16,
                                   sharding=shard(tok_spec))
    else:
        one = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                   sharding=shard(P(tok_spec[0], None)))
    return {"tokens": one}


def _sds(tree, mesh, spec_tree):
    """Attach shardings to an eval_shape pytree (specs re-fitted to shapes)."""
    return jax.tree.map(
        lambda t, sp: jax.ShapeDtypeStruct(
            t.shape, t.dtype,
            sharding=NamedSharding(mesh, S.fit_spec(mesh, t.shape, sp))),
        tree, spec_tree)


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh, remat="full",
               zero_opt: bool = True):
    """Returns (fn, example_args as ShapeDtypeStructs, in_shardings)."""
    key = jax.random.PRNGKey(0)
    params_tpl = jax.eval_shape(lambda: T.init_params(cfg, key))
    pspecs = S.param_specs(cfg, params_tpl, mesh)
    params_sds = _sds(params_tpl, mesh, pspecs)
    ins = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        opt_tpl = jax.eval_shape(lambda: init_opt_state(params_tpl))
        osp = (opt_specs(params_tpl, pspecs, mesh) if zero_opt else pspecs)
        ospecs = {"mu": osp, "nu": osp, "step": P()}
        opt_sds = _sds(opt_tpl, mesh, ospecs)
        step = make_train_step(cfg, AdamWConfig(),
                               remat="dots" if remat == "dots" else True)
        args = (params_sds, opt_sds, ins["inputs"], ins["labels"])
        return step, args

    if shape.kind == "prefill":
        def serve_prefill(params, inputs):
            logits, cache = T.forward_prefill(cfg, params, inputs,
                                              shape.seq_len, remat=True)
            return logits[:, -1], cache
        return serve_prefill, (params_sds, ins["inputs"])

    # decode
    cache_tpl = jax.eval_shape(
        lambda: T.init_decode_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs_d = S.kv_cache_specs(cfg, mesh, shape.global_batch)
    cspecs = {k: cspecs_d[k] for k in cache_tpl}
    cache_sds = _sds(cache_tpl, mesh, cspecs)

    def serve_decode(params, cache, tokens):
        pos = jnp.full((shape.global_batch,), shape.seq_len - 1, jnp.int32)
        logits, cache = T.forward_decode(cfg, params, cache, tokens, pos)
        return logits, cache
    return serve_decode, (params_sds, cache_sds, ins["tokens"])


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str,
            moe_impl: str = "gspmd", tag_suffix: str = "",
            pad_heads: int = 0, mesh_shape: str = "",
            kv_dtype: str = "", remat: str = "full",
            zero_opt: bool = True) -> dict:
    from repro.sharding.context import DistContext, distribution
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    skip = shape_skips(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": mesh_shape or ("2x16x16" if multi_pod else "16x16")}
    if moe_impl != "gspmd":
        rec["moe_impl"] = moe_impl
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        if outdir:
            os.makedirs(outdir, exist_ok=True)
            tag = (f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}"
                   + tag_suffix)
            with open(os.path.join(outdir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    cfg = variant_for_shape(cfg, shape)
    rec["attn_variant"] = cfg.attn_variant
    if kv_dtype:
        cfg = cfg.replace(kv_cache_dtype=kv_dtype)
        rec["kv_cache_dtype"] = kv_dtype
    if remat != "full":
        rec["remat"] = remat
    if pad_heads:
        # physical head padding (§Perf): round q/kv head counts up to a
        # multiple of the model-axis size so heads shard evenly (padded
        # heads have zero output rows — a layout change, not a model change)
        up = lambda n: -(-n // pad_heads) * pad_heads
        rec["padded_heads"] = [up(cfg.num_heads), up(cfg.num_kv_heads)]
        cfg = cfg.replace(num_heads=up(cfg.num_heads),
                          num_kv_heads=up(cfg.num_kv_heads))
    if mesh_shape:
        # alternative factorization of the same chip count (§Perf),
        # e.g. "32,8" = 256 chips with model=8 so 40 heads shard evenly
        dims = tuple(int(x) for x in mesh_shape.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(dims, axes)
        dp = axes[:-1]
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        dp = ("pod", "data") if multi_pod else ("data",)
    ctx = DistContext(mesh=mesh, data_axes=dp, moe_impl=moe_impl)
    t0 = time.time()
    try:
        fn, args = build_step(cfg, shape, mesh, remat=remat,
                              zero_opt=zero_opt)
        with distribution(ctx), mesh:
            lowered = jax.jit(fn).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        try:
            ca = compiled.cost_analysis()
            rec["flops"] = float(ca.get("flops", -1))
            rec["bytes"] = float(ca.get("bytes accessed", -1))
        except Exception as e:  # pragma: no cover
            rec["cost_analysis_error"] = str(e)
        try:
            ma = compiled.memory_analysis()
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                if hasattr(ma, f):
                    rec[f] = int(getattr(ma, f))
        except Exception as e:  # pragma: no cover
            rec["memory_analysis_error"] = str(e)
        try:
            rec["collective_bytes"] = collective_bytes(compiled.as_text())
        except Exception:
            rec["collective_bytes"] = collective_bytes(lowered.as_text())
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = (f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}"
               + tag_suffix)
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--moe", default="gspmd", choices=["gspmd", "ep"])
    ap.add_argument("--tag", default="", help="suffix for the output json")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="round head counts up to this multiple")
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh factorization, e.g. 32,8")
    ap.add_argument("--kv-dtype", default="", choices=["", "int8"])
    ap.add_argument("--remat", default="full", choices=["full", "dots"])
    ap.add_argument("--no-zero", action="store_true",
                    help="disable ZeRO optimizer-state sharding")
    args = ap.parse_args()
    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))
    for a, s in combos:
        rec = run_one(a, s, args.multi_pod, args.out, moe_impl=args.moe,
                      tag_suffix=args.tag, pad_heads=args.pad_heads,
                      mesh_shape=args.mesh_shape, kv_dtype=args.kv_dtype,
                      remat=args.remat, zero_opt=not args.no_zero)
        brief = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(brief))


if __name__ == "__main__":
    main()
