"""Checkpointing: flat-key npz snapshots of params + optimizer state."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, params, opt_state=None, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        flat.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    flat["meta/step"] = np.asarray(step)
    np.savez(path, **flat)


def load(path: str, params_template, opt_template=None):
    """Restore into the same pytree structure as the templates."""
    data = np.load(path)

    def restore(template, prefix):
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path_keys, leaf in flat_t:
            key = prefix + "/".join(_key_str(k) for k in path_keys)
            arr = data[key]
            if arr.dtype.kind == "V":
                # npz round-trips ml_dtypes (bfloat16, ...) as raw void
                arr = arr.view(np.dtype(leaf.dtype))
            leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(jax.tree.structure(template), leaves)

    params = restore(params_template, "params/")
    opt = restore(opt_template, "opt/") if opt_template is not None else None
    step = int(data["meta/step"])
    return params, opt, step


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
