"""Synthetic data pipeline: deterministic, seekable token / frame streams.

Produces next-token-prediction batches for text archs, frame batches for
the audio encoder, and interleaved text+VQ-token batches for the VLM —
matching each config's ``modality``.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


class TokenStream:
    """Markov-ish synthetic token stream (compressible => learnable)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        # low-entropy transition structure
        self._next = self.rng.integers(0, v, size=(v, 4))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        if cfg.modality == "audio_frames":
            frames = self.rng.standard_normal(
                (self.batch, self.seq_len, cfg.d_model)).astype(np.float32)
            labels = self.rng.integers(
                0, cfg.vocab_size, size=(self.batch, self.seq_len))
            return {"inputs": frames, "labels": labels.astype(np.int32)}
        toks = np.empty((self.batch, self.seq_len + 1), np.int64)
        toks[:, 0] = self.rng.integers(0, cfg.vocab_size, size=self.batch)
        choice = self.rng.integers(0, 4, size=(self.batch, self.seq_len))
        for t in range(self.seq_len):
            toks[:, t + 1] = self._next[toks[:, t], choice[:, t]]
        if cfg.modality == "vq_image+text":
            # interleave a block of "image tokens" (upper half of the vocab)
            span = self.seq_len // 4
            start = int(self.rng.integers(0, self.seq_len - span))
            toks[:, start:start + span] = self.rng.integers(
                cfg.vocab_size // 2, cfg.vocab_size,
                size=(self.batch, span))
        return {"inputs": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
