"""Train step: next-token cross-entropy (+ MoE aux loss) with AdamW."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, adamw_update


def loss_fn(cfg: ModelConfig, params, inputs, labels, remat=True):
    logits, aux = T.forward_full(cfg, params, inputs, remat=remat)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = jnp.mean(nll)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, remat=True):
    def train_step(params, opt_state, inputs, labels):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, inputs, labels, remat=remat),
            has_aux=True)(params)
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics
    return train_step
