"""AdamW optimizer (pure JAX, pytree-native) with cosine LR schedule."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
