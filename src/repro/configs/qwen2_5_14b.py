"""Qwen2.5-14B — dense, GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B family card]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", arch_type="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B (family); Qwen2.5 technical report",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2.5-14b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=1024,
)
