"""Mixtral-8x7B — MoE 8 experts top-2, GQA, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", arch_type="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, rope_theta=1_000_000.0,
    num_experts=8, experts_per_token=2,
    attn_variant="swa", sliding_window=4096,
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = CONFIG.replace(
    name="mixtral-8x7b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=1024,
    num_experts=4, experts_per_token=2, sliding_window=64,
)
