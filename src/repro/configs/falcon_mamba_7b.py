"""Falcon-Mamba-7B — attention-free Mamba1. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_version=1, ssm_expand=2, ssm_conv=4,
    source="arXiv:2410.05355",
)

SMOKE_CONFIG = CONFIG.replace(
    name="falcon-mamba-7b-smoke", num_layers=2, d_model=256, vocab_size=1024,
    ssm_state=8,
)
