"""StarCoder2-7B — dense, GQA, RoPE. [arXiv:2402.19173]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", arch_type="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152, rope_theta=100_000.0,
    source="arXiv:2402.19173",
)

SMOKE_CONFIG = CONFIG.replace(
    name="starcoder2-7b-smoke", num_layers=2, d_model=288, num_heads=9,
    num_kv_heads=3, head_dim=32, d_ff=512, vocab_size=1024,
)
