"""Zamba2-2.7B — hybrid Mamba2 backbone + one SHARED attention block applied
periodically (weight sharing across applications). [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_version=2, ssm_expand=2, ssm_heads=80,  # d_inner=5120, head 64
    shared_attn_every=6,
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.replace(
    name="zamba2-2.7b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=8, head_dim=32, d_ff=512, vocab_size=1024,
    ssm_state=16, ssm_heads=8, shared_attn_every=1,
)
