"""Any-to-any pipeline definitions (tiny, CPU-runnable) mirroring the
paper's evaluated models (§4.1):

  - qwen_omni   : Thinker (AR) -> Talker (AR) -> Vocoder (DiT or CNN)
                  [Qwen2.5-Omni Fig 4 / Qwen3-Omni]
  - glm_image   : AR LLM -> DiT image decoder            [GLM-Image]
  - bagel       : understanding AR -> generation DiT     [BAGEL, MoT-as-stages]
  - mimo_audio  : patch encoder -> AR LLM -> patch decoder [MiMo-Audio]

Each builder returns (StageGraph, engines dict). Model sizes are smoke-scale
so the serving benchmarks run on CPU; the stage graph machinery is the same
one the full configs would use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.config import EngineSpec
from repro.core.graph import StageGraph
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.diffusion_engine import (CustomEngine, DiffusionEngine,
                                           EncodeEngine)
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T
from repro.models.dit import DiTConfig, init_dit

D = 128  # shared hidden size of the tiny pipeline stages


def build_stage_engine(pipeline: str, stage: str, **kwargs):
    """Rebuild ONE stage engine of a named pipeline from builder kwargs.

    This is the module-level :class:`EngineSpec` target process replicas
    use: ``EngineSpec("repro.configs.pipelines:build_stage_engine",
    {"pipeline": "pd", "stage": "decode", ...})``.  The builders derive
    params deterministically from ``seed`` via ``init_params``, so an
    engine rebuilt in a spawned child carries byte-identical weights to
    the parent's — greedy decoding through a process replica matches the
    all-thread run exactly.  Rebuilding runs the full pipeline builder
    and keeps one stage; at the smoke scale these configs target, that
    cost is negligible next to the spawn itself.
    """
    builder = _BUILDERS.get(pipeline)
    if builder is None:
        raise ValueError(f"unknown pipeline {pipeline!r} "
                         f"(have {sorted(_BUILDERS)})")
    _, engines, _ = builder(**kwargs)
    if stage not in engines:
        raise ValueError(f"pipeline {pipeline!r} has no stage {stage!r} "
                         f"(have {sorted(engines)})")
    return engines[stage]


def stage_engine_specs(pipeline: str, stages, **kwargs):
    """Picklable per-stage :class:`EngineSpec` mapping for a pipeline
    built with exactly ``kwargs`` — what the builders put in their
    bundle's ``engine_specs`` entry and ``ServeConfig`` consumes for
    ``isolation='process'`` stages."""
    return {s: EngineSpec("repro.configs.pipelines:build_stage_engine",
                          {"pipeline": pipeline, "stage": s, **kwargs})
            for s in stages}


def tiny_lm(name: str, vocab: int = 512, layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name=name, arch_type="dense", num_layers=layers, d_model=D,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=vocab,
        dtype="float32", rope_theta=10_000.0)


def _kv(max_batch: int, max_seq: int = 256) -> PagedKVConfig:
    page = 16
    pages_per_seq = max_seq // page
    return PagedKVConfig(num_pages=max_batch * pages_per_seq + 8,
                         page_size=page, max_pages_per_seq=pages_per_seq)


# ----------------------------------------------------------------------------
# Qwen-Omni: Thinker -> Talker -> Vocoder
# ----------------------------------------------------------------------------

def build_qwen_omni(*, max_batch: int = 8, thinker_tokens: int = 24,
                    talker_tokens: int = 72, stream_chunk: int = 16,
                    vocoder_kind: str = "dit", dit_steps: int = 8,
                    cache_interval: int = 1, prefix_cache: bool = False,
                    seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    thinker_cfg = tiny_lm("thinker")
    talker_cfg = tiny_lm("talker", vocab=256)
    thinker_params = T.init_params(thinker_cfg, ks[0])
    talker_params = T.init_params(talker_cfg, ks[1])
    codec_embed = np.asarray(
        jax.random.normal(ks[2], (talker_cfg.vocab_size, D)) * 0.1,
        np.float32)

    def talker_preprocess(data, state):
        """Re-inject the Thinker hidden state at every Talker decode step."""
        h = data.get("thinker_hidden")
        if h is None or state["phase"] != "decode":
            return {}
        i = min(state["step"], h.shape[0] - 1)
        return {"extra_embed": h[i]}

    mm_proj = np.asarray(
        jax.random.normal(jax.random.fold_in(key, 9), (32, D)) * 0.1,
        np.float32)

    def mm_encode(data, state):
        """mm_encode hook (Fig 4): precomputed audio/image/video frontend
        embeddings (the stubbed modality frontend) are projected and
        concatenated ahead of the Thinker text prompt."""
        mm = data.get("mm_embeds")           # (frames, 32) from the stub
        if mm is None or state["phase"] != "prefill":
            return {}
        data["mm_frames_used"] = mm.shape[0]
        return {"prompt_prepend": np.asarray(mm, np.float32) @ mm_proj}

    # engine factories: replica 0 below is the first call; scale_up /
    # --replicas build extra replicas from the SAME initialized params
    # (each replica gets its own scheduler, allocator and KV pool)
    def make_thinker():
        return AREngine(
            "thinker", thinker_cfg, thinker_params, kv=_kv(max_batch),
            max_batch=max_batch, collect_hidden=True, preprocess=mm_encode,
            enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=thinker_tokens,
                                            temperature=0.8, top_k=20),
            seed=seed)

    def make_talker():
        return AREngine(
            "talker", talker_cfg, talker_params, kv=_kv(max_batch),
            max_batch=max_batch, preprocess=talker_preprocess,
            stream_chunk=stream_chunk, enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=talker_tokens,
                                            temperature=0.8, top_k=20),
            seed=seed + 1)

    thinker = make_thinker()
    talker = make_talker()

    if vocoder_kind == "dit":
        dit_cfg = DiTConfig(name="vocoder", num_layers=2, d_model=D,
                            num_heads=4, d_ff=256, in_dim=32, cond_dim=D,
                            num_steps=dit_steps)
        dit_params = init_dit(dit_cfg, ks[3])

        def make_vocoder():
            return DiffusionEngine(
                "vocoder", dit_cfg, dit_params,
                max_batch=max_batch, cache_interval=cache_interval,
                out_len_per_cond=2.0, seed=seed + 2)
        vocoder = make_vocoder()
    else:  # Qwen3-Omni style lightweight CNN vocoder
        wk = jax.random.split(ks[3], 2)
        w1 = jax.random.normal(wk[0], (3, D, D)) * 0.05
        w2 = jax.random.normal(wk[1], (3, D, 32)) * 0.05

        @jax.jit
        def _conv_stack(cond):   # (B, T, D) -> (B, 2T, 32)
            x = jax.lax.conv_general_dilated(
                cond, w1, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
            x = jax.nn.gelu(x)
            x = jnp.repeat(x, 2, axis=1)          # 2x upsample
            x = jax.lax.conv_general_dilated(
                x, w2, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC"))
            return x

        def vocode(batch_inputs):
            conds = [np.asarray(i["cond"]) for i in batch_inputs]
            tmax = max(c.shape[0] for c in conds)
            stacked = np.stack([np.pad(c, ((0, tmax - c.shape[0]), (0, 0)))
                                for c in conds])
            out = np.asarray(_conv_stack(jnp.asarray(stacked)))
            res = []
            for i, inp in enumerate(batch_inputs):
                n = inp["cond"].shape[0] * 2
                res.append({"latent": out[i, :n],
                            "chunk_index": inp.get("chunk_index", 0)})
            return res

        def make_vocoder():
            return CustomEngine("vocoder", vocode, max_batch=max_batch)
        vocoder = make_vocoder()

    graph = StageGraph()
    graph.add_stage(StageSpec("thinker", "ar"))
    graph.add_stage(StageSpec("talker", "ar"))
    graph.add_stage(StageSpec("vocoder",
                              "diffusion" if vocoder_kind == "dit"
                              else "custom", is_output=True))

    def thinker2talker(data, payload):
        data["thinker_hidden"] = payload["hidden"]
        data["thinker_tokens"] = payload["tokens"]
        return {"prompt_embeds": payload["hidden"]}

    def talker2vocoder(data, payload):
        toks = payload["tokens"]
        return {"cond": codec_embed[toks]}

    graph.add_edge("thinker", "talker", thinker2talker, connector="shm")
    graph.add_edge("talker", "vocoder", talker2vocoder, streaming=True,
                   connector="inline")
    engines = {"thinker": thinker, "talker": talker, "vocoder": vocoder}
    bundle = {"thinker_cfg": thinker_cfg, "thinker_params": thinker_params,
              "talker_cfg": talker_cfg, "talker_params": talker_params,
              "codec_embed": codec_embed,
              "thinker_tokens": thinker_tokens,
              "talker_tokens": talker_tokens,
              "engine_factories": {"thinker": make_thinker,
                                   "talker": make_talker,
                                   "vocoder": make_vocoder},
              "engine_specs": stage_engine_specs(
                  "qwen_omni", ("thinker", "talker", "vocoder"),
                  max_batch=max_batch, thinker_tokens=thinker_tokens,
                  talker_tokens=talker_tokens, stream_chunk=stream_chunk,
                  vocoder_kind=vocoder_kind, dit_steps=dit_steps,
                  cache_interval=cache_interval, prefix_cache=prefix_cache,
                  seed=seed)}
    return graph, engines, bundle


# ----------------------------------------------------------------------------
# GLM-Image / BAGEL: AR LLM -> DiT generator
# ----------------------------------------------------------------------------

def build_ar_dit(name: str = "glm_image", *, max_batch: int = 8,
                 ar_tokens: int = 32, image_latents: int = 64,
                 dit_steps: int = 8, cache_interval: int = 1,
                 prefix_cache: bool = False, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    llm_cfg = tiny_lm(f"{name}_llm")
    llm_params = T.init_params(llm_cfg, ks[0])
    vq_embed = np.asarray(
        jax.random.normal(ks[1], (llm_cfg.vocab_size, D)) * 0.1, np.float32)
    dit_cfg = DiTConfig(name=f"{name}_dit", num_layers=2, d_model=D,
                        num_heads=4, d_ff=256, in_dim=32, cond_dim=D,
                        num_steps=dit_steps)
    dit_params = init_dit(dit_cfg, ks[2])

    def make_llm():
        return AREngine(
            f"{name}_llm", llm_cfg, llm_params, kv=_kv(max_batch),
            max_batch=max_batch, collect_hidden=True,
            enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=ar_tokens,
                                            temperature=0.8, top_k=20),
            seed=seed)

    def make_dit():
        return DiffusionEngine(f"{name}_dit", dit_cfg, dit_params,
                               max_batch=max_batch,
                               cache_interval=cache_interval, seed=seed + 1)

    llm = make_llm()
    dit = make_dit()

    graph = StageGraph()
    graph.add_stage(StageSpec(f"{name}_llm", "ar"))
    graph.add_stage(StageSpec(f"{name}_dit", "diffusion", is_output=True))

    def llm2dit(data, payload):
        return {"cond": vq_embed[payload["tokens"]],
                "out_len": image_latents}

    graph.add_edge(f"{name}_llm", f"{name}_dit", llm2dit, connector="shm")
    return graph, {f"{name}_llm": llm, f"{name}_dit": dit}, {
        "llm_cfg": llm_cfg, "llm_params": llm_params, "vq_embed": vq_embed,
        "ar_tokens": ar_tokens, "image_latents": image_latents,
        "dit_cfg": dit_cfg,
        "engine_factories": {f"{name}_llm": make_llm,
                             f"{name}_dit": make_dit},
        "engine_specs": stage_engine_specs(
            name, (f"{name}_llm", f"{name}_dit"), max_batch=max_batch,
            ar_tokens=ar_tokens, image_latents=image_latents,
            dit_steps=dit_steps, cache_interval=cache_interval,
            prefix_cache=prefix_cache, seed=seed)}


# ----------------------------------------------------------------------------
# Prefill-Decode disaggregation (paper §3.4: the unified connector also
# carries intra-stage transfers — prompt KV from a prefill engine to a
# decode engine, vLLM PD-disaggregation style)
# ----------------------------------------------------------------------------

def build_pd_disaggregated(cfg: ModelConfig = None, *, max_batch: int = 4,
                           max_new: int = 8, temperature: float = 0.0,
                           connector: str = "shm",
                           prefix_cache: bool = False, seed: int = 0):
    import jax as _jax
    from repro.models import transformer as _T
    custom_cfg = cfg is not None
    cfg = cfg or tiny_lm("pd_lm", vocab=512)
    params = _T.init_params(cfg, _jax.random.PRNGKey(seed))

    def make_prefill():
        return AREngine(
            "prefill", cfg, params, kv=_kv(max_batch), max_batch=max_batch,
            emit_kv=True, collect_hidden=False,
            enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=1,
                                            temperature=temperature),
            seed=seed)

    def make_decode():
        return AREngine(
            "decode", cfg, params, kv=_kv(max_batch), max_batch=max_batch,
            default_sampling=SamplingParams(max_new_tokens=max_new,
                                            temperature=temperature),
            seed=seed)

    prefill = make_prefill()
    decode = make_decode()

    def prefill2decode(data, payload):
        return {"kv_seed": (payload["kv_k"], payload["kv_v"]),
                "prompt_len": payload["prompt_len"],
                "first_token": int(payload["tokens"][0])}

    graph = StageGraph()
    graph.add_stage(StageSpec("prefill", "ar"))
    graph.add_stage(StageSpec("decode", "ar", is_output=True))
    graph.add_edge("prefill", "decode", prefill2decode, connector=connector)
    spec_kwargs = dict(max_batch=max_batch, max_new=max_new,
                       temperature=temperature, connector=connector,
                       prefix_cache=prefix_cache, seed=seed)
    if custom_cfg:
        spec_kwargs["cfg"] = cfg             # ModelConfig pickles fine
    return graph, {"prefill": prefill, "decode": decode}, {
        "cfg": cfg, "params": params,
        "engine_factories": {"prefill": make_prefill,
                             "decode": make_decode},
        "engine_specs": stage_engine_specs("pd", ("prefill", "decode"),
                                           **spec_kwargs)}


# ----------------------------------------------------------------------------
# EPD disaggregation (paper §3.4 / Singh et al.): Encoder, Prefill and
# Decode each on their own engine; the MM cache (encoder embeddings) and
# the prompt KV both travel through the unified connector.
# ----------------------------------------------------------------------------

def build_epd_disaggregated(*, max_batch: int = 4, max_new: int = 8,
                            frame_dim: int = 32, connector: str = "shm",
                            seed: int = 0):
    import jax as _jax
    from repro.engine.diffusion_engine import EncodeEngine
    from repro.models import transformer as _T
    cfg = tiny_lm("epd_lm", vocab=512)
    params = _T.init_params(cfg, _jax.random.PRNGKey(seed))
    w_enc = np.asarray(
        _jax.random.normal(_jax.random.PRNGKey(seed + 1), (frame_dim, D))
        * 0.1, np.float32)

    def encode(batch_inputs):
        # stubbed modality frontend: frames -> prompt embeddings (MM cache)
        return [{"prompt_embeds": np.asarray(i["frames"], np.float32)
                 @ w_enc} for i in batch_inputs]

    def make_encoder():
        return EncodeEngine("encoder", encode, max_batch=max_batch)

    def make_prefill():
        return AREngine(
            "prefill", cfg, params, kv=_kv(max_batch), max_batch=max_batch,
            emit_kv=True,
            default_sampling=SamplingParams(max_new_tokens=1,
                                            temperature=0.0),
            seed=seed)

    def make_decode():
        return AREngine(
            "decode", cfg, params, kv=_kv(max_batch), max_batch=max_batch,
            default_sampling=SamplingParams(max_new_tokens=max_new,
                                            temperature=0.0),
            seed=seed)

    encoder = make_encoder()
    prefill = make_prefill()
    decode = make_decode()

    graph = StageGraph()
    graph.add_stage(StageSpec("encoder", "encode"))
    graph.add_stage(StageSpec("prefill", "ar"))
    graph.add_stage(StageSpec("decode", "ar", is_output=True))
    graph.add_edge("encoder", "prefill", lambda d, p: p,
                   connector=connector)            # MM cache hop
    graph.add_edge("prefill", "decode",
                   lambda d, p: {"kv_seed": (p["kv_k"], p["kv_v"]),
                                 "prompt_len": p["prompt_len"],
                                 "first_token": int(p["tokens"][0])},
                   connector=connector)            # prompt-KV hop
    return graph, {"encoder": encoder, "prefill": prefill,
                   "decode": decode}, {
        "cfg": cfg, "params": params, "w_enc": w_enc,
        "engine_factories": {"encoder": make_encoder,
                             "prefill": make_prefill,
                             "decode": make_decode},
        "engine_specs": stage_engine_specs(
            "epd", ("encoder", "prefill", "decode"), max_batch=max_batch,
            max_new=max_new, frame_dim=frame_dim, connector=connector,
            seed=seed)}


# ----------------------------------------------------------------------------
# MiMo-Audio: patch encoder -> AR LLM -> patch decoder
# ----------------------------------------------------------------------------

def build_mimo_audio(*, max_batch: int = 8, ar_tokens: int = 48,
                     patch: int = 4, prefix_cache: bool = False,
                     seed: int = 0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    llm_cfg = tiny_lm("mimo_llm")
    llm_params = T.init_params(llm_cfg, ks[0])
    w_enc = np.asarray(jax.random.normal(ks[1], (patch * 16, D)) * 0.1,
                       np.float32)
    w_dec = np.asarray(jax.random.normal(ks[2], (D, patch * 16)) * 0.1,
                       np.float32)
    tok_embed = np.asarray(
        jax.random.normal(ks[3], (llm_cfg.vocab_size, D)) * 0.1, np.float32)

    def encode(batch_inputs):
        res = []
        for inp in batch_inputs:
            audio = np.asarray(inp["audio"])        # (frames, 16)
            n = (audio.shape[0] // patch) * patch
            patches = audio[:n].reshape(-1, patch * 16)
            res.append({"prompt_embeds": patches @ w_enc})
        return res

    def decode(batch_inputs):
        res = []
        for inp in batch_inputs:
            emb = tok_embed[np.asarray(inp["tokens"])]
            res.append({"audio": emb @ w_dec})
        return res

    def make_enc():
        return EncodeEngine("patch_enc", encode, max_batch=max_batch)

    def make_llm():
        return AREngine(
            "mimo_llm", llm_cfg, llm_params, kv=_kv(max_batch),
            max_batch=max_batch, enable_prefix_cache=prefix_cache,
            default_sampling=SamplingParams(max_new_tokens=ar_tokens,
                                            temperature=0.8, top_k=20),
            seed=seed)

    def make_dec():
        return CustomEngine("patch_dec", decode, max_batch=max_batch)

    enc = make_enc()
    llm = make_llm()
    dec = make_dec()

    graph = StageGraph()
    graph.add_stage(StageSpec("patch_enc", "encode"))
    graph.add_stage(StageSpec("mimo_llm", "ar"))
    graph.add_stage(StageSpec("patch_dec", "custom", is_output=True))
    graph.add_edge("patch_enc", "mimo_llm", lambda d, p: p, connector="shm")
    graph.add_edge("mimo_llm", "patch_dec",
                   lambda d, p: {"tokens": p["tokens"]}, connector="inline")
    return graph, {"patch_enc": enc, "mimo_llm": llm, "patch_dec": dec}, {
        "llm_cfg": llm_cfg, "patch": patch,
        "engine_factories": {"patch_enc": make_enc, "mimo_llm": make_llm,
                             "patch_dec": make_dec},
        "engine_specs": stage_engine_specs(
            "mimo_audio", ("patch_enc", "mimo_llm", "patch_dec"),
            max_batch=max_batch, ar_tokens=ar_tokens, patch=patch,
            prefix_cache=prefix_cache, seed=seed)}


def _build_glm_image(**kw):
    return build_ar_dit("glm_image", **kw)


def _build_bagel(**kw):
    return build_ar_dit("bagel", **kw)


# build_stage_engine dispatch table (late-bound: the helper sits above
# the builders it names)
_BUILDERS = {
    "qwen_omni": build_qwen_omni,
    "glm_image": _build_glm_image,
    "bagel": _build_bagel,
    "pd": build_pd_disaggregated,
    "epd": build_epd_disaggregated,
    "mimo_audio": build_mimo_audio,
}
