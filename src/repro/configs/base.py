"""Config system: model architecture configs and input-shape configs.

Every assigned architecture gets a module ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (full size, dry-run only) and ``SMOKE_CONFIG``
(reduced: <=2 layers, d_model<=512, <=4 experts, runnable on CPU).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional

# Layer kind codes (per-layer layout string):
#   'A' = attention + MLP transformer block (dense / moe decided by cfg)
#   'M' = Mamba block (version per cfg.ssm_version)
#   'S' = shared-attention block boundary (zamba2: one globally shared
#         attention+MLP block applied between groups of Mamba layers)
LAYER_ATTN = "A"
LAYER_MAMBA = "M"
LAYER_SHARED = "S"


@dataclass(frozen=True)
class ModelConfig:
    """Architecture config covering dense / moe / ssm / hybrid / audio / vlm."""

    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                    # query heads ('A' layers); 0 for attn-free
    num_kv_heads: int
    d_ff: int                         # dense-MLP hidden dim (per-expert dim if MoE)
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Attention variant: "full" | "swa". sliding_window used when "swa".
    attn_variant: str = "full"
    sliding_window: int = 0
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    # expert capacity = ceil(T*k/E * capacity_factor); tokens overflowing an
    # expert's capacity are dropped (standard GShard/Switch semantics).
    # Set large (e.g. 1e9) to make routing lossless for exactness tests.
    capacity_factor: float = 1.25
    # SSM (mamba)
    ssm_state: int = 0
    ssm_version: int = 1              # 1 = Mamba1 (falcon-mamba), 2 = Mamba2 (zamba2)
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0                # mamba2 heads (d_inner // mamba2_head_dim)
    # Hybrid (zamba2): a shared attention block every `shared_attn_every`
    # Mamba layers, using ONE shared parameter set.
    shared_attn_every: int = 0
    # Decode KV-cache storage dtype: "" = model dtype; "int8" = quantized
    # per-(token, head) with f32 scales (vLLM-style fp8/int8 KV cache).
    kv_cache_dtype: str = ""
    # Encoder-only (hubert): bidirectional attention, no decode step.
    is_encoder: bool = False
    # Modality of the token stream. "text" and "vq_image+text" consume int32
    # token ids; "audio_frames" consumes precomputed float frame embeddings
    # (the conv feature extractor is a stub per assignment).
    modality: str = "text"
    dtype: str = "bfloat16"
    # provenance (source paper / model card for the config numbers)
    source: str = ""

    # ---- derived ----
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attn_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def layer_layout(self) -> str:
        """Per-layer kind string of length num_layers."""
        if self.arch_type == "ssm":
            return LAYER_MAMBA * self.num_layers
        if self.arch_type == "hybrid":
            # groups of `shared_attn_every` mamba layers; the shared attention
            # block is applied between groups (not counted as a layer).
            return LAYER_MAMBA * self.num_layers
        return LAYER_ATTN * self.num_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d = self.d_model
        n = 0
        # embeddings (+ untied head)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_layout:
            if kind == LAYER_ATTN:
                n += self._attn_params() + self._mlp_params()
            elif kind == LAYER_MAMBA:
                n += self._mamba_params()
        if self.arch_type == "hybrid" and self.shared_attn_every:
            n += self._attn_params() + self._mlp_params()  # one shared block
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.d_ff
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * per_expert * self.num_layers
        return total - inactive

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        return d * nq * hd + 2 * d * nkv * hd + nq * hd * d + (
            (nq + 2 * nkv) * hd if self.qkv_bias else 0)

    def _mlp_params(self) -> int:
        if self.is_moe:
            return self.num_experts * 3 * self.d_model * self.d_ff + self.d_model * self.num_experts
        return 3 * self.d_model * self.d_ff

    def _mamba_params(self) -> int:
        d, di, s = self.d_model, self.d_inner, self.ssm_state
        if self.ssm_version == 1:
            dt_rank = max(1, d // 16)
            return (d * 2 * di            # in_proj
                    + di * self.ssm_conv  # conv1d
                    + di * (dt_rank + 2 * s)  # x_proj
                    + dt_rank * di + di   # dt_proj
                    + di * s + di         # A_log, D
                    + di * d)             # out_proj
        # mamba2: in_proj -> [z, x, B, C, dt]
        nh = self.ssm_heads or max(1, di // 64)
        d_in_proj = 2 * di + 2 * s + nh
        return (d * d_in_proj + (di + 2 * s) * self.ssm_conv
                + nh * 3                  # A_log, D, dt_bias per head
                + di * d)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "qwen2_5_14b",
    "internlm2_1_8b",
    "qwen3_moe_30b_a3b",
    "zamba2_2_7b",
    "starcoder2_7b",
    "mixtral_8x7b",
    "qwen1_5_4b",
    "hubert_xlarge",
    "falcon_mamba_7b",
    "chameleon_34b",
]

# CLI ids (hyphens) -> module names
ARCH_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ARCH_ALIASES.update({
    "qwen2.5-14b": "qwen2_5_14b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "starcoder2-7b": "starcoder2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen1.5-4b": "qwen1_5_4b",
    "hubert-xlarge": "hubert_xlarge",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "chameleon-34b": "chameleon_34b",
})


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def shape_skips(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip-reason string if this (arch, shape) pair is skipped."""
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only arch has no decode step (DESIGN.md §4)"
    return None


def variant_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Adjust the config for a shape (e.g. SWA for 500k full-attention archs)."""
    if shape.name == "long_500k" and not cfg.is_attn_free:
        if cfg.attn_variant != "swa" and cfg.arch_type != "hybrid":
            # dense/moe/vlm full-attention archs run long_500k as the
            # documented sliding-window variant (DESIGN.md §4).
            return cfg.replace(attn_variant="swa", sliding_window=8192)
        if cfg.arch_type == "hybrid":
            return cfg.replace(attn_variant="swa", sliding_window=4096)
    return cfg
