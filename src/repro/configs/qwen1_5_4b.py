"""Qwen1.5-4B — dense, MHA (kv=heads), QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", arch_type="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151936, qkv_bias=True, rope_theta=5_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B (family)",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen1.5-4b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=8, head_dim=32, d_ff=512, vocab_size=1024,
)
