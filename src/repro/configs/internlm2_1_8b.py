"""InternLM2-1.8B — dense, GQA. [arXiv:2403.17297]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", arch_type="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92544, rope_theta=1_000_000.0,
    source="arXiv:2403.17297",
)

SMOKE_CONFIG = CONFIG.replace(
    name="internlm2-1.8b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=1024,
)
