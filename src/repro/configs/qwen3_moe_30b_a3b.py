"""Qwen3-30B-A3B — MoE, 128 experts top-8, GQA. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", arch_type="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936, rope_theta=1_000_000.0,
    num_experts=128, experts_per_token=8,
    source="hf:Qwen/Qwen3-30B-A3B",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-moe-30b-a3b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=1024,
    num_experts=4, experts_per_token=2,
)
