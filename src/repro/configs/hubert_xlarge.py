"""HuBERT-XLarge — encoder-only audio transformer (w2v2 arch). The conv
feature extractor is a stubbed frontend: inputs are precomputed frame
embeddings. [arXiv:2106.07447]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    is_encoder=True, modality="audio_frames",
    source="arXiv:2106.07447",
)

SMOKE_CONFIG = CONFIG.replace(
    name="hubert-xlarge-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=8, head_dim=32, d_ff=512, vocab_size=64,
)
