"""Chameleon-34B — early-fusion VLM, VQ image tokens share the text vocab.
The VQ image tokenizer is the stubbed frontend: inputs are interleaved
text+image token ids. [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", arch_type="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=65536, rope_theta=10_000.0,
    modality="vq_image+text",
    source="arXiv:2405.09818",
)

SMOKE_CONFIG = CONFIG.replace(
    name="chameleon-34b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=1024,
)
