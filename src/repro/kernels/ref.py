"""Pure-jnp reference oracles for every Pallas kernel.

These are the numerically-trusted implementations: the engines run them on
CPU (this container), the Pallas kernels are validated against them in
``tests/test_kernels.py`` with ``interpret=True``, and the dry-run lowers
them for roofline analysis.

Attention uses grouped (GQA) einsums — K/V are never materially repeated to
``num_heads``, so HLO FLOPs/bytes match what a real GQA kernel would do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -2.0 ** 30  # large-negative instead of -inf: keeps fully-masked


def _group(q: jax.Array, nkv: int) -> jax.Array:
    """(B,S,nq,hd) -> (B,S,nkv,g,hd)."""
    b, s, nq, hd = q.shape
    return q.reshape(b, s, nkv, nq // nkv, hd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """Full-sequence attention oracle.

    q: (B, Sq, nq, hd); k, v: (B, Sk, nkv, hd); nq % nkv == 0.
    window > 0 => sliding-window: key j visible to query i iff
    i - window < j <= i (plus causality).
    """
    b, sq, nq, hd = q.shape
    nkv = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    qg = _group(q, nkv).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg * scale,
                        k.astype(jnp.float32))  # (B,nkv,g,Sq,Sk)
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (prefill-extend)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, nq, hd).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None,
                     key_positions: jax.Array | None = None) -> jax.Array:
    """Single-token decode attention against a dense per-request KV cache.

    q: (B, 1, nq, hd); caches: (B, S, nkv, hd); pos: (B,) index of the
    current token (cache already contains it). k_scale/v_scale: optional
    (B, S, nkv) dequant scales for int8-quantized caches — HBM reads stay
    1 byte/elem; dequant fuses into the contraction. key_positions:
    optional (B, S) absolute position of every cache column (ring-buffer
    SWA caches); defaults to arange(S).
    """
    b, _, nq, hd = q.shape
    nkv = k_cache.shape[2]
    s = k_cache.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    k_cache, v_cache = kf, vf
    qg = _group(q, nkv).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg * scale,
                        k_cache.astype(jnp.float32))  # (B,nkv,g,1,S)
    if key_positions is not None:
        j = key_positions
    else:
        j = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    mask = (j <= pos[:, None]) & (j >= 0)
    if window > 0:
        mask &= j > (pos[:, None] - window)
    scores = jnp.where(mask[:, None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, nq, hd).astype(q.dtype)


def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array, *,
                    window: int = 0, scale: float | None = None,
                    k_scale_pages: jax.Array | None = None,
                    v_scale_pages: jax.Array | None = None) -> jax.Array:
    """Decode attention over a block-paged KV cache (vLLM PagedAttention).

    q: (B, nq, hd) — one query token per sequence.
    k_pages/v_pages: (num_pages, page_size, nkv, hd) — the global page pool.
    block_tables: (B, pages_per_seq) int32 page ids (padded arbitrarily).
    seq_lens: (B,) int32 — number of valid tokens (incl. current).
    k/v_scale_pages: optional (num_pages, page_size, nkv) dequant scales for
    int8-quantized page pools.
    """
    b, nq, hd = q.shape
    num_pages, page, nkv, _ = k_pages.shape
    scale = scale if scale is not None else hd ** -0.5
    k = k_pages[block_tables].astype(jnp.float32)  # (B, pp, page, nkv, hd)
    v = v_pages[block_tables].astype(jnp.float32)
    if k_scale_pages is not None:
        k = k * k_scale_pages[block_tables].astype(jnp.float32)[..., None]
    if v_scale_pages is not None:
        v = v * v_scale_pages[block_tables].astype(jnp.float32)[..., None]
    pp = block_tables.shape[1]
    k = k.reshape(b, pp * page, nkv, hd)
    v = v.reshape(b, pp * page, nkv, hd)
    qg = q.reshape(b, 1, nkv, nq // nkv, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg * scale, k.astype(jnp.float32))
    j = jnp.arange(pp * page)[None, :]
    mask = j < seq_lens[:, None]
    if window > 0:
        mask &= j > (seq_lens[:, None] - 1 - window)
    scores = jnp.where(mask[:, None, None, None, :], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(b, nq, hd).astype(q.dtype)


def chunk_attention(q: jax.Array, k_all: jax.Array, v_all: jax.Array,
                    q_start: jax.Array, *, window: int = 0,
                    scale: float | None = None) -> jax.Array:
    """Chunked-prefill attention: C query tokens at absolute positions
    [q_start, q_start+C) attend over a gathered KV history.

    q: (B, C, nq, hd); k_all/v_all: (B, T, nkv, hd) with keys valid on
    [0, q_start + C) (causality masks the rest). q_start: (B,) or scalar.
    """
    b, c, nq, hd = q.shape
    nkv, t = k_all.shape[2], k_all.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    qg = _group(q, nkv).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg * scale,
                        k_all.astype(jnp.float32))
    qs = jnp.broadcast_to(jnp.asarray(q_start), (b,))
    qpos = qs[:, None, None] + jnp.arange(c)[None, :, None]   # (B,C,1)
    kpos = jnp.arange(t)[None, None, :]                       # (1,1,T)
    mask = kpos <= qpos
    if window > 0:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[:, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v_all.astype(jnp.float32))
    return out.reshape(b, c, nq, hd).astype(q.dtype)


# ----------------------------------------------------------------------------
# Mamba selective scans
# ----------------------------------------------------------------------------

def mamba1_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, h0: jax.Array | None = None):
    """Mamba1 selective scan.

    x, dt: (Bt, S, di); A: (di, n); B, C: (Bt, S, n); D: (di,).
    h0: optional initial state (Bt, di, n). Returns (y (Bt,S,di), h_last).
    """
    bt, s, di = x.shape
    n = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((bt, di, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt_, ct = inp  # (Bt,di), (Bt,di), (Bt,n), (Bt,n)
        dA = jnp.exp(dtt[..., None] * Af[None])          # (Bt,di,n)
        dBx = dtt[..., None] * bt_[:, None, :] * xt[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.swapaxes(0, 1) + xf * D.astype(jnp.float32)[None, None]
    return y.astype(x.dtype), h


def mamba2_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, h0: jax.Array | None = None):
    """Mamba2 (SSD) scan with scalar-per-head A.

    x: (Bt, S, nh, hp); dt: (Bt, S, nh); A, D: (nh,); B, C: (Bt, S, n).
    Returns (y (Bt,S,nh,hp), h_last (Bt,nh,hp,n)).
    """
    bt, s, nh, hp = x.shape
    n = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = B.astype(jnp.float32), C.astype(jnp.float32), A.astype(jnp.float32)
    h = jnp.zeros((bt, nh, hp, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt_, ct = inp  # (Bt,nh,hp), (Bt,nh), (Bt,n), (Bt,n)
        dA = jnp.exp(dtt * Af[None])                      # (Bt,nh)
        dBx = (dtt[..., None, None] * xt[..., None]) * bt_[:, None, None, :]
        h = dA[..., None, None] * h + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (xf.swapaxes(0, 1), dtf.swapaxes(0, 1),
          Bf.swapaxes(0, 1), Cf.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.swapaxes(0, 1) + xf * Df_broadcast(D, xf)
    return y.astype(x.dtype), h


def Df_broadcast(D: jax.Array, xf: jax.Array) -> jax.Array:
    return D.astype(jnp.float32)[None, None, :, None]
