"""Pallas TPU flash attention (prefill / full-sequence, causal + GQA + SWA).

TPU-native design notes (vs the CUDA flash-attention the paper's engines use):
  - Tiling is (BQ, head_dim) query tiles × (BK, head_dim) key tiles sized for
    VMEM; BQ/BK default 128 so the MXU matmuls are (128 × hd) @ (hd × 128) —
    fully aligned to the 128×128 systolic array.
  - The KV axis is the LAST grid dimension: on TPU the last grid dim is
    sequential, so the online-softmax running state (m, l, acc) lives in VMEM
    scratch and persists across KV steps; the output tile is written once at
    the final KV step (no atomics, no HBM round-trips — the TPU analogue of
    the warp-level reduction in the GPU kernel).
  - GQA: the kernel indexes K/V by q_head // group via the BlockSpec
    index_map, so K/V tiles are fetched once per kv-head group.

Validated against kernels/ref.py with interpret=True in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0 ** 30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               sk: int, sq: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
    s = q @ k.T                                          # (BQ, BK)

    # positions for masking (query positions aligned to the end of keys)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (sk - sq)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                                  # (BQ, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v_ref[0, 0].astype(jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == pl.num_programs(3) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, nq, hd); k, v: (B, Sk, nkv, hd) -> (B, Sq, nq, hd)."""
    b, sq, nq, hd = q.shape
    _, sk, nkv, _ = k.shape
    g = nq // nkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = hd ** -0.5

    qt = q.transpose(0, 2, 1, 3)  # (B, nq, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, nkv, Sk, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, nq, sq // bq, sk // bk)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, sk=sk, sq=sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
