"""Pallas TPU paged attention (single-token decode over a block-paged KV
cache) — the TPU adaptation of vLLM's PagedAttention CUDA kernel.

TPU-native design notes:
  - The GPU kernel assigns a warp per page and reduces in shared memory.
    On TPU we instead make the page axis the LAST (sequential) grid
    dimension and carry the online-softmax state in VMEM scratch — same
    dataflow, systolic-friendly.
  - Page indirection uses PrefetchScalarGridSpec: ``block_tables`` and
    ``seq_lens`` are scalar-prefetch operands, so each grid step's
    BlockSpec index_map dereferences the page id *before* the DMA is
    issued — the TPU equivalent of the GPU kernel's pointer chasing, with
    the DMA engine doing the gather.
  - Pages are (page_size, head_dim) tiles; page_size is a multiple of 8
    (sublane) and head_dim a multiple of 128 lanes for aligned VMEM tiles.
  - GQA: all g query heads of one kv head are processed together as the
    rows of a (g, hd) MXU tile.

Validated against kernels/ref.py (interpret=True) in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -2.0 ** 30


def _pa_kernel(block_tables_ref, seq_lens_ref,  # scalar prefetch
               q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *,
               page: int, window: int, ks_ref=None, vs_ref=None):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (g, hd) — pre-scaled
    k = k_ref[0, :, 0].astype(jnp.float32)               # (page, hd)
    if ks_ref is not None:
        # int8 page pool: dequantize in-VMEM (HBM traffic stays 1 B/elem)
        k = k * ks_ref[0, :, 0][:, None].astype(jnp.float32)
    s = q @ k.T                                          # (g, page)

    seq_len = seq_lens_ref[b]
    tok = pi * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = tok < seq_len
    if window > 0:
        mask &= tok > seq_len - 1 - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, :, 0].astype(jnp.float32)
    if vs_ref is not None:
        v = v * vs_ref[0, :, 0][:, None].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pa_kernel_quant(bt_ref, sl_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                     o_ref, m_ref, l_ref, acc_ref, *, page, window):
    _pa_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
               acc_ref, page=page, window=window, ks_ref=ks_ref,
               vs_ref=vs_ref)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    block_tables: jax.Array, seq_lens: jax.Array, *,
                    k_scale_pages: jax.Array | None = None,
                    v_scale_pages: jax.Array | None = None,
                    window: int = 0, interpret: bool = False) -> jax.Array:
    """q: (B, nq, hd); k/v_pages: (P, page, nkv, hd);
    block_tables: (B, pages_per_seq) int32; seq_lens: (B,) int32.
    Optional k/v_scale_pages: (P, page, nkv) f32 — int8-quantized pool with
    in-kernel dequantization. Returns (B, nq, hd)."""
    b, nq, hd = q.shape
    num_pages, page, nkv, _ = k_pages.shape
    pp = block_tables.shape[1]
    g = nq // nkv
    scale = hd ** -0.5
    quant = k_scale_pages is not None

    # (B, nkv, g, hd) so each kv head's query group is one tile
    qg = (q * scale).reshape(b, nkv, g, hd)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd),
                     lambda b_, h, p, bt, sl: (b_, h, 0, 0)),
        # dereference the page id from the prefetched block table
        pl.BlockSpec((1, page, 1, hd),
                     lambda b_, h, p, bt, sl: (bt[b_, p], 0, h, 0)),
        pl.BlockSpec((1, page, 1, hd),
                     lambda b_, h, p, bt, sl: (bt[b_, p], 0, h, 0)),
    ]
    operands = [block_tables, seq_lens, qg, k_pages, v_pages]
    if quant:
        in_specs += [
            pl.BlockSpec((1, page, 1),
                         lambda b_, h, p, bt, sl: (bt[b_, p], 0, h)),
            pl.BlockSpec((1, page, 1),
                         lambda b_, h, p, bt, sl: (bt[b_, p], 0, h)),
        ]
        operands += [k_scale_pages, v_scale_pages]
        kernel = functools.partial(_pa_kernel_quant, page=page,
                                   window=window)
    else:
        kernel = functools.partial(_pa_kernel, page=page, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nkv, pp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h, p, bt, sl: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, nq, hd)
