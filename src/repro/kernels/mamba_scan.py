"""Pallas TPU Mamba1 selective scan.

TPU-native design notes:
  - The CUDA selective-scan kernel parallelizes over channels with one
    thread block per (batch, channel-chunk) and scans sequentially in
    registers. On TPU we tile channels into (BD,) VMEM blocks (BD a
    multiple of 128 lanes) and make the sequence-chunk axis the LAST
    (sequential) grid dimension; the recurrent state h (BD, n) persists in
    VMEM scratch across sequence chunks.
  - Within a chunk the recurrence is a lax.fori_loop over BS timesteps on
    (BD, n) VREG tiles — elementwise VPU work; the state never round-trips
    to HBM (the GPU version's shared-memory trick, done with VMEM scratch).

Validated against kernels/ref.py (interpret=True) in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_ref, *, bs: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    A = a_ref[...].astype(jnp.float32)            # (BD, n)
    D = d_ref[...].astype(jnp.float32)            # (1, BD)

    def step(t, _):
        xt = x_ref[0, t].astype(jnp.float32)      # (BD,)
        dtt = dt_ref[0, t].astype(jnp.float32)    # (BD,)
        Bt = b_ref[0, t].astype(jnp.float32)      # (n,)
        Ct = c_ref[0, t].astype(jnp.float32)      # (n,)
        h = h_ref[...]
        dA = jnp.exp(dtt[:, None] * A)            # (BD, n)
        h = dA * h + (dtt * xt)[:, None] * Bt[None, :]
        h_ref[...] = h
        y = jnp.sum(h * Ct[None, :], axis=-1) + D[0] * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, step, 0)

    @pl.when(si == pl.num_programs(2) - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bd", "bs", "interpret"))
def mamba1_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, h0: jax.Array | None = None, *,
                bd: int = 256, bs: int = 64, interpret: bool = False):
    """x, dt: (Bt, S, di); A: (di, n); B, C: (Bt, S, n); D: (di,).
    Returns (y (Bt,S,di) fp32-accurate, h_last (Bt,di,n) f32)."""
    bt, s, di = x.shape
    n = A.shape[1]
    bd = min(bd, di)
    bs = min(bs, s)
    assert di % bd == 0 and s % bs == 0
    if h0 is None:
        h0 = jnp.zeros((bt, di, n), jnp.float32)

    grid = (bt, di // bd, s // bs)
    y, h = pl.pallas_call(
        functools.partial(_scan_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, d_, s_: (b_, s_, d_)),  # x
            pl.BlockSpec((1, bs, bd), lambda b_, d_, s_: (b_, s_, d_)),  # dt
            pl.BlockSpec((bd, n), lambda b_, d_, s_: (d_, 0)),           # A
            pl.BlockSpec((1, bs, n), lambda b_, d_, s_: (b_, s_, 0)),    # B
            pl.BlockSpec((1, bs, n), lambda b_, d_, s_: (b_, s_, 0)),    # C
            pl.BlockSpec((1, bd), lambda b_, d_, s_: (0, d_)),           # D
            pl.BlockSpec((1, bd, n), lambda b_, d_, s_: (b_, d_, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, d_, s_: (b_, s_, d_)),  # y
            pl.BlockSpec((1, bd, n), lambda b_, d_, s_: (b_, d_, 0)),    # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), x.dtype),
            jax.ShapeDtypeStruct((bt, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D.reshape(1, di), h0)
    return y, h
