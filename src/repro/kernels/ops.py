"""Jitted dispatch wrappers around the compute hot-spot kernels.

Backend selection:
  - "ref":     pure-jnp oracle (kernels/ref.py) — default on CPU; also what
               the multi-pod dry-run lowers (GSPMD-shardable HLO).
  - "pallas":  the Pallas TPU kernels (interpret=True off-TPU).
  - "auto":    "pallas" on TPU, else "ref".

Set globally with ``set_backend`` or per-call with ``backend=``.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref

_BACKEND = "auto"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("auto", "ref", "pallas")
    _BACKEND = name


def get_backend(override: str | None = None) -> str:
    b = override or _BACKEND
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return b


def flash_attention(q, k, v, *, causal=True, window=0, backend=None):
    if get_backend(backend) == "pallas":
        return flash_attention_trainable(q, k, v, causal, window)
    return ref.flash_attention(q, k, v, causal=causal, window=window)


# Pallas forward + recompute backward: makes the TPU kernel usable inside
# jax.grad (train_step). The backward differentiates the jnp oracle — the
# standard flash-attention recompute pattern (no O(S^2) residuals saved).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_trainable(q, k, v, causal, window):
    from repro.kernels import flash_attention as fa
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=jax.default_backend() != "tpu")


def _fa_fwd(q, k, v, causal, window):
    return flash_attention_trainable(q, k, v, causal, window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention(q_, k_, v_, causal=causal,
                                               window=window), q, k, v)
    return vjp(g)


flash_attention_trainable.defvjp(_fa_fwd, _fa_bwd)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, backend=None,
                     k_scale=None, v_scale=None, key_positions=None):
    # dense-cache decode: kernel-wise this is paged attention with one page
    # per sequence; we keep a dedicated ref path (used by the dry-run).
    return ref.decode_attention(q, k_cache, v_cache, pos, window=window,
                                k_scale=k_scale, v_scale=v_scale,
                                key_positions=key_positions)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    window=0, backend=None, k_scale_pages=None,
                    v_scale_pages=None):
    if get_backend(backend) == "pallas":
        from repro.kernels import paged_attention as pa
        return pa.paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                                  window=window,
                                  k_scale_pages=k_scale_pages,
                                  v_scale_pages=v_scale_pages,
                                  interpret=jax.default_backend() != "tpu")
    return ref.paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                               window=window, k_scale_pages=k_scale_pages,
                               v_scale_pages=v_scale_pages)


def mamba1_scan(x, dt, A, B, C, D, h0=None, *, backend=None):
    if get_backend(backend) == "pallas":
        from repro.kernels import mamba_scan as ms
        return ms.mamba1_scan(x, dt, A, B, C, D, h0,
                              interpret=jax.default_backend() != "tpu")
    return ref.mamba1_scan(x, dt, A, B, C, D, h0)


def mamba2_scan(x, dt, A, B, C, D, h0=None, *, backend=None):
    return ref.mamba2_scan(x, dt, A, B, C, D, h0)
