"""Unified model definition covering all assigned architecture families.

One functional API over dense / MoE / SSM / hybrid / encoder / VLM configs:

  init_params(cfg, key)                      -> params
  forward_full(cfg, params, inputs)          -> (logits, aux)      train/encode
  forward_prefill(cfg, params, inputs, S_max)-> (logits, cache)    fill cache
  init_decode_cache(cfg, batch, S_max)       -> cache
  forward_decode(cfg, params, cache, tok, pos)-> (logits, cache)   one token

Layers are scanned (stacked params) for compile-time sanity at 512 devices;
the zamba2 hybrid scans Mamba groups with ONE shared attention block applied
between groups (weight sharing preserved; per-application-site KV caches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p: dict = {}
    if cfg.modality != "audio_frames":
        p["embed"] = (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dtype)
    p["final_ln"] = L.init_rmsnorm(cfg.d_model, dtype)
    p["lm_head"] = L._dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                 cfg.d_model, dtype)
    layout = cfg.layer_layout
    if cfg.arch_type == "hybrid":
        p["mamba"] = _stack_init(lambda k: M.init_mamba(cfg, k), ks[2],
                                 cfg.num_layers)
        p["shared_attn"] = L.init_block(cfg, ks[3])  # single shared block
    elif cfg.arch_type == "ssm":
        p["mamba"] = _stack_init(lambda k: M.init_mamba(cfg, k), ks[2],
                                 cfg.num_layers)
    else:
        p["blocks"] = _stack_init(lambda k: L.init_block(cfg, k), ks[2],
                                  cfg.num_layers)
    return p


def _embed(cfg: ModelConfig, params: dict, inputs: jax.Array) -> jax.Array:
    if cfg.modality == "audio_frames":
        return inputs  # precomputed frame embeddings (stub frontend)
    return params["embed"][inputs]


def _unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(params["final_ln"], x, cfg.rmsnorm_eps)
    return x @ params["lm_head"]


def _n_sites(cfg: ModelConfig) -> int:
    """Hybrid: number of shared-attention application sites."""
    return cfg.num_layers // cfg.shared_attn_every


# ----------------------------------------------------------------------------
# full-sequence forward (train / encode / prefill compute)
# ----------------------------------------------------------------------------

def _remat_wrap(body, remat):
    """remat: False | True ("full") | "dots" (save matmul outputs — avoids
    recomputing TP collectives in the backward pass at higher live memory)."""
    if not remat:
        return body
    if remat == "dots":
        return jax.checkpoint(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body, prevent_cse=False)


def forward_full(cfg: ModelConfig, params: dict, inputs: jax.Array,
                 positions: jax.Array | None = None, remat=True):
    """inputs: int32 tokens (B,S) or float frames (B,S,d). -> (logits, aux)."""
    x = _embed(cfg, params, inputs)
    B, S = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.arange(S)[None, :]
    causal = not cfg.is_encoder

    if cfg.arch_type in ("ssm", "hybrid"):
        x = _backbone_ssm_full(cfg, params, x, positions, remat)
        aux = jnp.zeros((), jnp.float32)
    else:
        def body(carry, lp):
            h, aux = carry
            h, a = L.block_full(cfg, lp, h, positions, causal=causal)
            return (h, aux + a), None
        body = _remat_wrap(body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
    return _unembed(cfg, params, x), aux


def _backbone_ssm_full(cfg, params, x, positions, remat):
    def mbody(h, lp):
        h, _ = M.mamba_block(cfg, lp, h)
        return h, None
    mbody = _remat_wrap(mbody, remat)
    if cfg.arch_type == "ssm":
        x, _ = jax.lax.scan(mbody, x, params["mamba"])
        return x

    # hybrid: scan groups of `shared_attn_every` mamba layers, applying the
    # single shared attention block between groups.
    g = _n_sites(cfg)
    gs = cfg.shared_attn_every
    grouped = jax.tree.map(lambda a: a.reshape(g, gs, *a.shape[1:]),
                           params["mamba"])
    shared = params["shared_attn"]

    def gbody(h, glp):
        h, _ = jax.lax.scan(mbody, h, glp)
        h, _ = L.block_full(cfg, shared, h, positions, causal=True)
        return h, None
    gbody = _remat_wrap(gbody, remat)
    x, _ = jax.lax.scan(gbody, x, grouped)
    return x


# ----------------------------------------------------------------------------
# decode cache
# ----------------------------------------------------------------------------

def _kv_store_dtype(cfg: ModelConfig):
    return jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.dtype(cfg.dtype)


def kv_cache_seq(cfg: ModelConfig, max_seq: int) -> int:
    """SWA caches are ring buffers of `sliding_window` columns — the 500k
    SWA decode cache is 64x smaller than the sequence."""
    if cfg.attn_variant == "swa" and 0 < cfg.sliding_window < max_seq:
        return cfg.sliding_window
    return max_seq


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dtype = _kv_store_dtype(cfg)
    max_seq = kv_cache_seq(cfg, max_seq)
    cache: dict = {}
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
        if cfg.kv_cache_dtype == "int8":
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    if cfg.arch_type in ("ssm", "hybrid"):
        h, conv = M.init_mamba_state(cfg, batch)
        n = cfg.num_layers
        cache["ssm_h"] = jnp.zeros((n, *h.shape), h.dtype)
        cache["ssm_conv"] = jnp.zeros((n, *conv.shape), conv.dtype)
    if cfg.arch_type == "hybrid":
        ns = _n_sites(cfg)
        shape = (ns, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
        cache["k"] = jnp.zeros(shape, dtype)
        cache["v"] = jnp.zeros(shape, dtype)
        if cfg.kv_cache_dtype == "int8":
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    return cache


# ----------------------------------------------------------------------------
# decode step (one new token against the cache)
# ----------------------------------------------------------------------------

def forward_decode(cfg: ModelConfig, params: dict, cache: dict,
                   tokens: jax.Array, pos: jax.Array):
    """tokens: (B,1) int32 (or (B,1,d) frames); pos: scalar or (B,).
    Returns (logits (B,1,V), new_cache)."""
    x = _embed(cfg, params, tokens)

    quant = cfg.kv_cache_dtype == "int8"
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(h, xs):
            lp, kc, vc, ks, vs = xs
            h, kc, vc, ks, vs = L.block_decode(cfg, lp, h, pos, kc, vc,
                                               ks, vs)
            return h, (kc, vc, ks, vs)
        scales = ((cache["k_scale"], cache["v_scale"]) if quant
                  else (None, None))
        x, (k, v, ks, vs) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"], *scales))
        new_cache = {"k": k, "v": v}
        if quant:
            new_cache.update({"k_scale": ks, "v_scale": vs})
    elif cfg.arch_type == "ssm":
        def body(h, xs):
            lp, sh, sc = xs
            h, (sh, sc) = M.mamba_block(cfg, lp, h, (sh, sc))
            return h, (sh, sc)
        x, (sh, sc) = jax.lax.scan(body, x, (params["mamba"], cache["ssm_h"],
                                             cache["ssm_conv"]))
        new_cache = {"ssm_h": sh, "ssm_conv": sc}
    else:  # hybrid
        g, gs = _n_sites(cfg), cfg.shared_attn_every
        grouped = jax.tree.map(lambda a: a.reshape(g, gs, *a.shape[1:]),
                               params["mamba"])
        sh_g = cache["ssm_h"].reshape(g, gs, *cache["ssm_h"].shape[1:])
        sc_g = cache["ssm_conv"].reshape(g, gs, *cache["ssm_conv"].shape[1:])
        shared = params["shared_attn"]

        def mbody(h, xs):
            lp, s_h, s_c = xs
            h, (s_h, s_c) = M.mamba_block(cfg, lp, h, (s_h, s_c))
            return h, (s_h, s_c)

        def gbody(h, xs):
            glp, s_h, s_c, kc, vc, ks, vs = xs
            h, (s_h, s_c) = jax.lax.scan(mbody, h, (glp, s_h, s_c))
            h, kc, vc, ks, vs = L.block_decode(cfg, shared, h, pos, kc, vc,
                                               ks, vs)
            return h, (s_h, s_c, kc, vc, ks, vs)

        scales = ((cache["k_scale"], cache["v_scale"]) if quant
                  else (None, None))
        x, (sh, sc, k, v, ks, vs) = jax.lax.scan(
            gbody, x, (grouped, sh_g, sc_g, cache["k"], cache["v"], *scales))
        new_cache = {
            "ssm_h": sh.reshape(cfg.num_layers, *sh.shape[2:]),
            "ssm_conv": sc.reshape(cfg.num_layers, *sc.shape[2:]),
            "k": k, "v": v,
        }
        if quant:
            new_cache.update({"k_scale": ks, "v_scale": vs})
    return _unembed(cfg, params, x), new_cache


# ----------------------------------------------------------------------------
# prefill: full-seq compute that also fills the decode cache
# ----------------------------------------------------------------------------

def forward_prefill(cfg: ModelConfig, params: dict, inputs: jax.Array,
                    max_seq: int, remat: bool = True):
    """Process the prompt and return (logits (B,S,V), filled cache).

    The cache is sized to ``max_seq``; prompt K/V occupy [0, S).
    """
    x = _embed(cfg, params, inputs)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]

    def attn_prefill(lp, h):
        """Run one attention block full-seq, returning (h, (k_S, v_S))."""
        hn = L.rmsnorm(lp["ln1"], h, cfg.rmsnorm_eps)
        q, k, v = L._qkv(cfg, lp["attn"], hn)
        if cfg.head_dim and cfg.rope_theta and not cfg.is_encoder:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
        from repro.kernels import ops
        window = cfg.sliding_window if cfg.attn_variant == "swa" else 0
        o = ops.flash_attention(q, k, v, causal=True, window=window)
        h = h + jnp.einsum("bsqh,qhd->bsd", o, lp["attn"]["wo"])
        hn = L.rmsnorm(lp["ln2"], h, cfg.rmsnorm_eps)
        if cfg.is_moe:
            from repro.models import moe as moe_mod
            y, _ = moe_mod.moe_forward(cfg, lp["moe"], hn)
        else:
            y = L.mlp(lp["mlp"], hn)
        return h + y, (k, v)

    cache_seq = kv_cache_seq(cfg, max_seq)

    def _to_cache_layout(a, axis):
        """Lay prompt K/V (seq length S) into the cache's seq columns.

        Plain cache: right-pad to cache_seq. Ring (SWA) cache of w columns:
        column j holds the latest prompt position p ≡ j (mod w); earlier
        positions are overwritten, matching decode-time wrapping.
        """
        axis = axis % a.ndim
        ring = (cfg.attn_variant == "swa" and cfg.sliding_window > 0
                and cache_seq == cfg.sliding_window)
        if not ring:
            pad = [(0, 0)] * a.ndim
            pad[axis] = (0, cache_seq - S)
            return jnp.pad(a, pad)
        w = cache_seq
        j = jnp.arange(w)
        p = (S - 1) - ((S - 1 - j) % w)          # latest pos per column
        valid = p >= 0
        gathered = jnp.take(a, jnp.clip(p, 0, S - 1), axis=axis)
        mask_shape = [1] * a.ndim
        mask_shape[axis] = w
        return jnp.where(valid.reshape(mask_shape), gathered, 0)

    def pad_cache(kv):
        """Lay prompt K/V into the cache; quantize if configured."""
        k, v = kv  # (L?, B, S, nkv, hd)
        out = {}
        if cfg.kv_cache_dtype == "int8":
            kq, ks = L.quantize_kv(k)
            vq, vs = L.quantize_kv(v)
            out["k"] = _to_cache_layout(kq, -3)
            out["v"] = _to_cache_layout(vq, -3)
            out["k_scale"] = _to_cache_layout(ks, -2)
            out["v_scale"] = _to_cache_layout(vs, -2)
        else:
            out["k"] = _to_cache_layout(k, -3)
            out["v"] = _to_cache_layout(v, -3)
        return out

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(h, lp):
            h, kv = attn_prefill(lp, h)
            return h, kv
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (k, v) = jax.lax.scan(body, x, params["blocks"])
        cache = pad_cache((k, v))
    elif cfg.arch_type == "ssm":
        def body(h, lp):
            h, st = M.mamba_block(cfg, lp, h)
            return h, st
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (sh, sc) = jax.lax.scan(body, x, params["mamba"])
        cache = {"ssm_h": sh, "ssm_conv": sc}
    else:  # hybrid
        g, gs = _n_sites(cfg), cfg.shared_attn_every
        grouped = jax.tree.map(lambda a: a.reshape(g, gs, *a.shape[1:]),
                               params["mamba"])
        shared = params["shared_attn"]

        def mbody(h, lp):
            h, st = M.mamba_block(cfg, lp, h)
            return h, st

        def gbody(h, glp):
            h, st = jax.lax.scan(mbody, h, glp)
            h, kv = attn_prefill(shared, h)
            return h, (st, kv)
        if remat:
            gbody = jax.checkpoint(gbody, prevent_cse=False)
        x, ((sh, sc), (k, v)) = jax.lax.scan(gbody, x, grouped)
        cache = pad_cache((k, v))
        cache.update({
            "ssm_h": sh.reshape(cfg.num_layers, *sh.shape[2:]),
            "ssm_conv": sc.reshape(cfg.num_layers, *sc.shape[2:]),
        })
    return _unembed(cfg, params, x), cache
