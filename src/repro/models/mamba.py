"""Mamba blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Full-sequence (train / prefill) and single-token decode paths. The decode
"KV cache" of an SSM layer is a constant-size recurrent state — the engine's
per-stage cache manager swaps paged-KV for this (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def mamba2_head_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner // (cfg.ssm_heads or max(1, cfg.d_inner // 64))


def n_heads2(cfg: ModelConfig) -> int:
    return cfg.ssm_heads or max(1, cfg.d_inner // 64)


def init_mamba(cfg: ModelConfig, key) -> dict:
    d, di, n, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {"ln": init_rmsnorm(d, dtype)}
    if cfg.ssm_version == 1:
        r = dt_rank(cfg)
        p.update({
            "in_proj": _dense_init(ks[0], (d, 2 * di), d, dtype),
            "conv_w": _dense_init(ks[1], (cw, di), cw, dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "x_proj": _dense_init(ks[2], (di, r + 2 * n), di, dtype),
            "dt_proj": _dense_init(ks[3], (r, di), r, dtype),
            "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus ~ small dt
            "A_log": jnp.log(jnp.broadcast_to(
                jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": _dense_init(ks[4], (di, d), di, dtype),
        })
    else:
        nh = n_heads2(cfg)
        conv_ch = di + 2 * n
        p.update({
            # in_proj -> [z (di), x (di), B (n), C (n), dt (nh)]
            "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * n + nh), d, dtype),
            "conv_w": _dense_init(ks[1], (cw, conv_ch), cw, dtype),
            "conv_b": jnp.zeros((conv_ch,), dtype),
            "dt_bias": jnp.full((nh,), -4.0, jnp.float32),
            "A_log": jnp.zeros((nh,), jnp.float32),
            "D": jnp.ones((nh,), jnp.float32),
            "gate_ln": init_rmsnorm(di, dtype),
            "out_proj": _dense_init(ks[4], (di, d), di, dtype),
        })
    return p


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None):
    """Depthwise causal conv along S. x: (B,S,ch); w: (cw,ch).

    state: (B, cw-1, ch) trailing inputs from the previous segment (or None
    for zero history). Returns (y (B,S,ch), new_state (B, cw-1, ch)).
    """
    cw = w.shape[0]
    B, S, ch = x.shape
    if state is None:
        state = jnp.zeros((B, cw - 1, ch), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+cw-1, ch)
    y = sum(xp[:, i:i + S] * w[i][None, None] for i in range(cw))
    new_state = xp[:, S:]  # last cw-1 inputs
    return jax.nn.silu(y + b[None, None]), new_state


def mamba1_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                   state: tuple | None = None):
    """x: (B,S,d). state: (h (B,di,n), conv (B,cw-1,di)) or None.
    Returns (y (B,S,d), new_state)."""
    di, n = cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)
    h0, conv0 = state if state is not None else (None, None)
    xz = x @ p["in_proj"]                              # (B,S,2di)
    xs, z = xz[..., :di], xz[..., di:]
    xs, conv_state = _causal_conv(xs, p["conv_w"], p["conv_b"], conv0)
    proj = xs @ p["x_proj"]                            # (B,S,r+2n)
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj"]
                         + p["dt_bias"].astype(x.dtype))
    Bm, Cm = proj[..., r:r + n], proj[..., r + n:]
    A = -jnp.exp(p["A_log"])                           # (di,n)
    y, h = ops.mamba1_scan(xs, dt, A, Bm, Cm, p["D"], h0)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], (h, conv_state)


def mamba2_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                   state: tuple | None = None):
    """x: (B,S,d). state: (h (B,nh,hp,n), conv (B,cw-1,di+2n)) or None."""
    di, n = cfg.d_inner, cfg.ssm_state
    nh, hp = n_heads2(cfg), mamba2_head_dim(cfg)
    h0, conv0 = state if state is not None else (None, None)
    proj = x @ p["in_proj"]                            # (B,S,2di+2n+nh)
    z = proj[..., :di]
    xbc = proj[..., di:2 * di + 2 * n]
    dt = jax.nn.softplus(proj[..., 2 * di + 2 * n:]
                         + p["dt_bias"].astype(x.dtype))  # (B,S,nh)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv0)
    xs = xbc[..., :di].reshape(*x.shape[:2], nh, hp)
    Bm, Cm = xbc[..., di:di + n], xbc[..., di + n:]
    A = -jnp.exp(p["A_log"])                           # (nh,)
    y, h = ops.mamba2_scan(xs, dt, A, Bm, Cm, p["D"], h0)
    y = y.reshape(*x.shape[:2], di)
    y = rmsnorm(p["gate_ln"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    return y @ p["out_proj"], (h, conv_state)


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array,
                state: tuple | None = None):
    """Pre-norm residual Mamba block. Returns (x, new_state)."""
    fwd = mamba1_forward if cfg.ssm_version == 1 else mamba2_forward
    y, new_state = fwd(cfg, p, rmsnorm(p["ln"], x, cfg.rmsnorm_eps), state)
    return x + y, new_state


def init_mamba_state(cfg: ModelConfig, batch: int):
    """Zero recurrent state for one Mamba layer."""
    di, n, cw = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dtype = jnp.dtype(cfg.dtype)
    if cfg.ssm_version == 1:
        h = jnp.zeros((batch, di, n), jnp.float32)
        conv = jnp.zeros((batch, cw - 1, di), dtype)
    else:
        nh, hp = n_heads2(cfg), mamba2_head_dim(cfg)
        h = jnp.zeros((batch, nh, hp, n), jnp.float32)
        conv = jnp.zeros((batch, cw - 1, di + 2 * n), dtype)
    return (h, conv)
