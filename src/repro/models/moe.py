"""Mixture-of-Experts layer: top-k routing with per-expert capacity,
sort-based dispatch (no (T,E,C) one-hot blowup), and load-balance aux loss.

This is the GSPMD-friendly baseline formulation: everything is gathers,
scatters and batched einsums over a static (E, C, d) buffer, so the expert
axis shards cleanly over the "model" mesh axis (expert parallelism). The
shard_map all-to-all variant lives in ``moe_ep.py`` (§Perf optimization).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

def init_moe(cfg: ModelConfig, key) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E), d, jnp.float32),
        "wg": _dense_init(ks[1], (E, d, f), d, dtype),
        "wu": _dense_init(ks[2], (E, d, f), d, dtype),
        "wd": _dense_init(ks[3], (E, f, d), f, dtype),
    }


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(tokens * cfg.experts_per_token / cfg.num_experts
                  * cfg.capacity_factor)
    return max(8, min(c, tokens))


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array):
    """x: (B, S, d) -> (y (B,S,d), aux_loss scalar)."""
    from repro.models import moe_ep
    if moe_ep.ep_applicable(cfg):
        return moe_ep.moe_forward_ep(cfg, p, x)
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    C = capacity(T, cfg)
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]          # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                   # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)    # renormalize

    # ---- sort-based dispatch -------------------------------------------
    e_flat = topi.reshape(T * k)
    sort_idx = jnp.argsort(e_flat)                         # (T*k,)
    e_sorted = e_flat[sort_idx]
    counts = jnp.bincount(e_flat, length=E)                # (E,)
    offsets = jnp.cumsum(counts) - counts                  # exclusive
    pos_in_e = jnp.arange(T * k) - offsets[e_sorted]       # slot within expert
    tok = sort_idx // k                                    # source token id

    # scatter into the (E, C, d) compute buffer; slots >= C are dropped
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[e_sorted, pos_in_e].set(xf[tok], mode="drop")

    # ---- expert compute (grouped einsum; E shards over "model") -------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])         # (E, C, d)

    # ---- gather back + combine ----------------------------------------
    keep = (pos_in_e < C)
    y_sorted = y_buf[e_sorted, jnp.minimum(pos_in_e, C - 1)]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_flat = jnp.zeros((T * k, d), x.dtype).at[sort_idx].set(y_sorted)
    y = (y_flat.reshape(T, k, d)
         * topw[..., None].astype(x.dtype)).sum(axis=1)

    # ---- load-balance aux loss (Switch-style) --------------------------
    frac = counts.astype(jnp.float32) / (T * k)            # dispatch fraction
    prob = jnp.mean(gates, axis=0)                         # mean router prob
    aux = cfg.router_aux_coef * E * jnp.sum(frac * prob)
    return y.reshape(B, S, d), aux
