"""Expert-parallel MoE via shard_map (§Perf optimization, beyond paper).

Why: the GSPMD formulation in moe.py sorts the GLOBAL token stream; with
tokens sharded over "data" the partitioner materializes all-gathers of the
full activation set (measured: 213 GB/device/step for qwen3-moe train_4k).

This variant keeps everything local:
  - tokens stay on their data shard (activations are replicated across the
    "model" axis, as in standard TP);
  - expert weights are sharded over the "model" axis (E_loc = E / tp);
  - each model rank dispatches ITS OWN slice of experts for the local
    tokens (local sort, local capacity) and computes partial outputs;
  - one psum over "model" combines partial expert outputs — the SAME
    collective volume as a dense TP MLP (2 * T_loc * d), instead of
    gathering the global token stream.

Capacity semantics become per-(data-shard, expert) — the standard
per-device-capacity behavior of production MoE systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models import moe as moe_base
from repro.sharding.context import get_context


def _local_moe(cfg: ModelConfig, model_axis: str, dp_axes):
    """Builds the per-shard function run inside shard_map."""
    k = cfg.experts_per_token

    def fn(x, router, wg, wu, wd):
        # x: (B_loc, S, d) local tokens (replicated over model axis)
        # router: (d, E) replicated; wg/wu/wd: (E_loc, d, f) local experts
        B, S, d = x.shape
        E_loc = wg.shape[0]
        rank = jax.lax.axis_index(model_axis)
        e_lo = rank * E_loc
        T = B * S
        xf = x.reshape(T, d)

        logits = xf.astype(jnp.float32) @ router            # (T, E)
        gates = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(gates, k)
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

        C = moe_base.capacity(T, cfg)
        # mask the (token, k) pairs owned by this rank's experts
        local = (topi >= e_lo) & (topi < e_lo + E_loc)       # (T, k)
        e_flat = jnp.where(local, topi - e_lo, E_loc).reshape(T * k)
        sort_idx = jnp.argsort(e_flat)
        e_sorted = e_flat[sort_idx]
        counts = jnp.bincount(e_flat, length=E_loc + 1)
        offsets = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * k) - offsets[e_sorted]
        tok = sort_idx // k

        buf = jnp.zeros((E_loc, C, d), x.dtype)
        oob = (e_sorted >= E_loc) | (pos_in_e >= C)
        buf = buf.at[jnp.where(oob, E_loc, e_sorted),
                     jnp.minimum(pos_in_e, C - 1)].set(
            jnp.where(oob[:, None], 0, xf[tok]), mode="drop")

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        y_buf = jnp.einsum("ecf,efd->ecd", h, wd)

        y_sorted = y_buf[jnp.minimum(e_sorted, E_loc - 1),
                         jnp.minimum(pos_in_e, C - 1)]
        y_sorted = jnp.where(oob[:, None], 0, y_sorted)
        y_flat = jnp.zeros((T * k, d), x.dtype).at[sort_idx].set(y_sorted)
        y = (y_flat.reshape(T, k, d)
             * topw[..., None].astype(x.dtype)).sum(axis=1)
        # combine partial expert outputs across the model axis
        y = jax.lax.psum(y, model_axis)

        # load-balance aux (global fractions via psum)
        full_counts = jnp.zeros((cfg.num_experts,), jnp.float32)
        full_counts = jax.lax.dynamic_update_slice(
            full_counts, counts[:E_loc].astype(jnp.float32), (e_lo,))
        full_counts = jax.lax.psum(full_counts, model_axis)
        # counts over all experts sum to the local T*k dispatched pairs
        # (each model rank fills only its expert slice — no double count)
        frac = full_counts / jnp.float32(T * k)
        prob = jnp.mean(gates, axis=0)           # local mean
        aux = cfg.router_aux_coef * cfg.num_experts * jnp.sum(frac * prob)
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)    # replicate across data
        return y.reshape(B, S, d), aux

    return fn


def moe_forward_ep(cfg: ModelConfig, p: dict, x: jax.Array):
    """Drop-in replacement for moe.moe_forward when a DistContext is set."""
    from repro.sharding import specs as S
    ctx = get_context()
    assert ctx is not None
    dp = S.batch_spec(ctx.mesh, x.shape[0])      # None if B doesn't divide
    fn = _local_moe(cfg, ctx.model_axis, dp)
    mapped = shard_map(
        fn, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None),
                  P(ctx.model_axis, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_rep=False)
    return mapped(x, p["router"], p["wg"], p["wu"], p["wd"])


def ep_applicable(cfg: ModelConfig) -> bool:
    ctx = get_context()
    return (ctx is not None and ctx.moe_impl == "ep"
            and cfg.num_experts % ctx.mesh.shape[ctx.model_axis] == 0)
