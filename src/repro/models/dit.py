"""Diffusion Transformer (DiT) — the generator stage for vocoder / image /
video synthesis (Peebles & Xie 2023 style, adaLN-zero conditioning, with
cross-attention to conditioning tokens from the upstream AR stage).

Used by the diffusion engine (rectified-flow Euler sampling) for the
Talker→Vocoder and AR→image pipelines in the paper's evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import _dense_init, init_rmsnorm, rmsnorm


@dataclass(frozen=True)
class DiTConfig:
    name: str = "dit"
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    d_ff: int = 1024
    in_dim: int = 64          # latent channels per position
    cond_dim: int = 256       # conditioning token dim (upstream hidden size)
    num_steps: int = 20       # default denoising steps
    rmsnorm_eps: float = 1e-6
    dtype: str = "float32"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def timestep_embedding(t: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal embedding of t in [0,1]. t: (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t[:, None].astype(jnp.float32) * 1000.0 * freqs[None]
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def init_dit(cfg: DiTConfig, key) -> dict:
    d, f, nh, hd = cfg.d_model, cfg.d_ff, cfg.num_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 16)

    def blk(k):
        kk = jax.random.split(k, 10)
        return {
            "ln1": init_rmsnorm(d, dt),
            "wq": _dense_init(kk[0], (d, nh, hd), d, dt),
            "wk": _dense_init(kk[1], (d, nh, hd), d, dt),
            "wv": _dense_init(kk[2], (d, nh, hd), d, dt),
            "wo": _dense_init(kk[3], (nh, hd, d), d, dt),
            "ln_x": init_rmsnorm(d, dt),
            "xwq": _dense_init(kk[4], (d, nh, hd), d, dt),
            "xwk": _dense_init(kk[5], (cfg.cond_dim, nh, hd), cfg.cond_dim, dt),
            "xwv": _dense_init(kk[6], (cfg.cond_dim, nh, hd), cfg.cond_dim, dt),
            "xwo": _dense_init(kk[7], (nh, hd, d), d, dt),
            "ln2": init_rmsnorm(d, dt),
            "wg": _dense_init(kk[8], (d, f), d, dt),
            "wd": _dense_init(kk[9], (f, d), f, dt),
            # adaLN-zero: 6 modulations (shift/scale/gate for attn and mlp)
            "ada": jnp.zeros((d, 6 * d), dt),
        }

    return {
        "in_proj": _dense_init(ks[0], (cfg.in_dim, d), cfg.in_dim, dt),
        "t_mlp1": _dense_init(ks[1], (d, d), d, dt),
        "t_mlp2": _dense_init(ks[2], (d, d), d, dt),
        "blocks": jax.vmap(blk)(jax.random.split(ks[3], cfg.num_layers)),
        "final_ln": init_rmsnorm(d, dt),
        "out_proj": jnp.zeros((d, cfg.in_dim), dt),  # zero-init output
    }


def _attn(cfg: DiTConfig, q_in, kv_in, wq, wk, wv, wo):
    q = jnp.einsum("bsd,dqh->bsqh", q_in, wq)
    k = jnp.einsum("bsd,dqh->bsqh", kv_in, wk)
    v = jnp.einsum("bsd,dqh->bsqh", kv_in, wv)
    o = ops.flash_attention(q, k, v, causal=False)
    return jnp.einsum("bsqh,qhd->bsd", o, wo)


def dit_forward(cfg: DiTConfig, params: dict, x_t: jax.Array, t: jax.Array,
                cond: jax.Array) -> jax.Array:
    """Predict velocity. x_t: (B, T, in_dim); t: (B,); cond: (B, Tc, cond_dim)."""
    h = x_t @ params["in_proj"]
    temb = timestep_embedding(t, cfg.d_model).astype(h.dtype)
    temb = jax.nn.silu(temb @ params["t_mlp1"]) @ params["t_mlp2"]  # (B, d)

    def body(h, lp):
        mods = jnp.split(jax.nn.silu(temb) @ lp["ada"], 6, axis=-1)
        sh1, sc1, g1, sh2, sc2, g2 = [m[:, None, :] for m in mods]
        a = rmsnorm(lp["ln1"], h, cfg.rmsnorm_eps) * (1 + sc1) + sh1
        h = h + g1 * _attn(cfg, a, a, lp["wq"], lp["wk"], lp["wv"], lp["wo"])
        xa = rmsnorm(lp["ln_x"], h, cfg.rmsnorm_eps)
        h = h + _attn(cfg, xa, cond, lp["xwq"], lp["xwk"], lp["xwv"], lp["xwo"])
        m = rmsnorm(lp["ln2"], h, cfg.rmsnorm_eps) * (1 + sc2) + sh2
        h = h + g2 * (jax.nn.silu(m @ lp["wg"]) @ lp["wd"])
        return h, None

    h, _ = jax.lax.scan(body, h, params["blocks"])
    h = rmsnorm(params["final_ln"], h, cfg.rmsnorm_eps)
    return h @ params["out_proj"]


def sample(cfg: DiTConfig, params: dict, cond: jax.Array, out_len: int,
           key, num_steps: int | None = None,
           cache_interval: int = 1) -> jax.Array:
    """Rectified-flow Euler sampler: integrate dx/dt = v from t=1 (noise) to 0.

    cache_interval > 1 enables TeaCache-style reuse: the velocity is
    recomputed every `cache_interval` steps and reused in between.
    """
    steps = num_steps or cfg.num_steps
    b = cond.shape[0]
    x = jax.random.normal(key, (b, out_len, cfg.in_dim), dtype=jnp.dtype(cfg.dtype))
    dt = 1.0 / steps

    def body(i, carry):
        x, v_cached = carry
        t = 1.0 - i * dt
        recompute = (i % cache_interval) == 0
        v = jax.lax.cond(
            recompute,
            lambda: dit_forward(cfg, params, x, jnp.full((b,), t), cond),
            lambda: v_cached)
        return x - dt * v, v

    v0 = jnp.zeros_like(x)
    x, _ = jax.lax.fori_loop(0, steps, body, (x, v0))
    return x
