"""Core neural layers shared by every architecture: RMSNorm, RoPE, GQA
attention (full / sliding-window / decode-with-cache), SwiGLU MLP.

Everything is functional: ``init_*`` builds a param pytree, ``apply_*``
consumes it. Params are plain nested dicts of jnp arrays so they stack
cleanly over layers for ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / np.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embeddings.

    x: (..., S, H, hd); positions: broadcastable to (..., S). Uses the
    split-half convention (matches most open-weight LLMs).
    """
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention (GQA, optional bias / sliding window / encoder-bidirectional)
# ----------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, nq, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, nkv, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, nkv, hd), d, dtype),
        "wo": _dense_init(ks[3], (nq, hd, d), nq * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    return p


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dqh->bsqh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attention_full(cfg: ModelConfig, p: dict, x: jax.Array,
                   positions: jax.Array, causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder)."""
    q, k, v = _qkv(cfg, p, x)
    if cfg.head_dim and cfg.rope_theta and not cfg.is_encoder:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    window = cfg.sliding_window if cfg.attn_variant == "swa" else 0
    o = ops.flash_attention(q, k, v, causal=causal, window=window)
    return jnp.einsum("bsqh,qhd->bsd", o, p["wo"])


def quantize_kv(x: jax.Array):
    """Per-(token, head) int8 symmetric quantization.

    x: (..., hd) -> (int8 (..., hd), scale (...,) f32).
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None):
    """One-token decode against a dense KV cache.

    x: (B, 1, d); pos: scalar or (B,) current position; caches (B, S, nkv, hd).
    With cfg.kv_cache_dtype == "int8", caches are int8 and k_scale/v_scale
    hold the (B, S, nkv) dequant scales.
    Returns (out (B,1,d), new caches...) — scales returned iff quantized.
    """
    q, k, v = _qkv(cfg, p, x)  # q (B,1,nq,hd), k/v (B,1,nkv,hd)
    posb = jnp.broadcast_to(jnp.asarray(pos), (x.shape[0],))  # (B,)
    if cfg.head_dim and cfg.rope_theta:
        q = rope(q, posb[:, None], cfg.rope_theta)
        k = rope(k, posb[:, None], cfg.rope_theta)
    bidx = jnp.arange(x.shape[0])
    S = k_cache.shape[1]
    window = cfg.sliding_window if cfg.attn_variant == "swa" else 0
    # Ring-buffer SWA cache: when the cache holds only `window` columns
    # (init_decode_cache sizes SWA caches to the window), writes wrap and
    # column j holds absolute position pos - ((pos - j) mod S).
    ring = bool(window) and S == window
    write_idx = posb % S if ring else posb
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = k_cache.at[bidx, write_idx].set(kq[:, 0])
        v_cache = v_cache.at[bidx, write_idx].set(vq[:, 0])
        k_scale = k_scale.at[bidx, write_idx].set(ks[:, 0])
        v_scale = v_scale.at[bidx, write_idx].set(vs[:, 0])
    else:
        k_cache = k_cache.at[bidx, write_idx].set(k[:, 0])
        v_cache = v_cache.at[bidx, write_idx].set(v[:, 0])
    key_positions = None
    if ring:
        j = jnp.arange(S)[None, :]
        key_positions = posb[:, None] - ((posb[:, None] - j) % S)
    o = ops.decode_attention(q, k_cache, v_cache, posb, window=window,
                             k_scale=k_scale, v_scale=v_scale,
                             key_positions=key_positions)
    out = jnp.einsum("bsqh,qhd->bsd", o.astype(x.dtype), p["wo"])
    return out, k_cache, v_cache, k_scale, v_scale


# ----------------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (d, f), d, dtype),
        "wu": _dense_init(ks[1], (d, f), d, dtype),
        "wd": _dense_init(ks[2], (f, d), f, dtype),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    return h @ p["wd"]


# ----------------------------------------------------------------------------
# Transformer block (attention + MLP/MoE), pre-norm
# ----------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key) -> dict:
    from repro.models import moe as moe_mod
    ks = jax.random.split(key, 3)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
        "attn": init_attention(cfg, ks[0]),
        "ln2": init_rmsnorm(cfg.d_model, jnp.dtype(cfg.dtype)),
    }
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(cfg, ks[1])
    else:
        p["mlp"] = init_mlp(cfg, ks[1])
    return p


def block_full(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               causal: bool = True):
    """Full-seq transformer block. Returns (x, aux_loss)."""
    from repro.models import moe as moe_mod
    x = x + attention_full(cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.rmsnorm_eps),
                           positions, causal=causal)
    h = rmsnorm(p["ln2"], x, cfg.rmsnorm_eps)
    if cfg.is_moe:
        y, aux = moe_mod.moe_forward(cfg, p["moe"], h)
    else:
        y, aux = mlp(p["mlp"], h), jnp.zeros((), jnp.float32)
    return x + y, aux


def block_decode(cfg: ModelConfig, p: dict, x: jax.Array, pos: jax.Array,
                 k_cache: jax.Array, v_cache: jax.Array,
                 k_scale: jax.Array | None = None,
                 v_scale: jax.Array | None = None):
    from repro.models import moe as moe_mod
    a, k_cache, v_cache, k_scale, v_scale = attention_decode(
        cfg, p["attn"], rmsnorm(p["ln1"], x, cfg.rmsnorm_eps), pos,
        k_cache, v_cache, k_scale, v_scale)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.rmsnorm_eps)
    if cfg.is_moe:
        y, _ = moe_mod.moe_forward(cfg, p["moe"], h)
    else:
        y = mlp(p["mlp"], h)
    return x + y, k_cache, v_cache, k_scale, v_scale
