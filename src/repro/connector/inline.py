"""Inline connector: control-queue pass-by-reference for small payloads
(single-node, same-process engines)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

from repro.connector.base import Connector


class InlineConnector(Connector):
    name = "inline"

    def __init__(self) -> None:
        super().__init__()
        self._store_map: Dict[str, Any] = {}

    def _store(self, key: str, payload: Any) -> float:
        self._store_map[key] = payload
        return 0.0

    def _load(self, key: str) -> Tuple[Any, float]:
        return self._store_map[key], 0.0

    def _evict(self, key: str) -> None:
        self._store_map.pop(key, None)
