"""Inline connector: control-queue pass-by-reference for small payloads
(single-node, same-process engines).

No copy is made: ``send`` publishes the object reference and ``recv``
hands it straight to the consumer, so cross-thread visibility is provided
entirely by the base class's lock/condition pair.  The base class's
identity ``_pack``/``_unpack`` and dict ``_publish``/``_fetch``/``_evict``
are exactly that behavior."""
from __future__ import annotations

from repro.connector.base import Connector


class InlineConnector(Connector):
    name = "inline"
