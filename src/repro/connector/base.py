"""Unified connector interface (paper §3.4).

A connector moves intermediate data objects (embeddings, hidden states,
codec tokens, audio/image tensors — and intra-stage KV / MM caches) between
stages through a common put/get interface; only lightweight metadata rides
the control plane.

On this CPU container the three backends model the paper's deployment
topologies:
  - InlineConnector   — control-queue pass-by-reference (small payloads).
  - SharedMemoryConnector — single-node shm: payloads are serialized into a
    host buffer pool (a real copy, like /dev/shm) and deserialized on get.
  - MooncakeConnector — multi-node put/get store: serializing copy on both
    ends + a bandwidth/latency cost model for the TCP/RDMA hop.

On real TPU the payload hop is a ``jax.device_put`` onto the destination
stage's submesh (ICI/DCN); connectors count bytes either way so Table 1 can
be reproduced.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


@dataclass
class TransferStats:
    calls: int = 0
    bytes: int = 0
    wall_time: float = 0.0       # measured time spent in put+get
    modeled_time: float = 0.0    # cost-model time (e.g. RDMA hop)

    def record(self, nbytes: int, wall: float, modeled: float = 0.0) -> None:
        self.calls += 1
        self.bytes += nbytes
        self.wall_time += wall
        self.modeled_time += modeled


def payload_nbytes(payload: Any) -> int:
    leaves = jax.tree.leaves(payload)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        elif isinstance(leaf, (bytes, bytearray)):
            total += len(leaf)
        elif isinstance(leaf, (int, float, bool)):
            total += 8
        elif isinstance(leaf, str):
            total += len(leaf)
    return total


class Connector:
    """put/get data plane + metadata control plane."""

    name = "base"

    def __init__(self) -> None:
        self.stats = TransferStats()
        self._meta: Dict[str, dict] = {}

    # -- control plane ---------------------------------------------------
    def metadata(self, key: str) -> Optional[dict]:
        return self._meta.get(key)

    # -- data plane -------------------------------------------------------
    def put(self, key: str, payload: Any) -> None:
        t0 = time.perf_counter()
        nbytes = payload_nbytes(payload)
        modeled = self._store(key, payload)
        self._meta[key] = {"nbytes": nbytes, "t_put": t0}
        self.stats.record(nbytes, time.perf_counter() - t0, modeled)

    def get(self, key: str) -> Any:
        t0 = time.perf_counter()
        payload, modeled = self._load(key)
        self.stats.wall_time += time.perf_counter() - t0
        self.stats.modeled_time += modeled
        return payload

    def delete(self, key: str) -> None:
        self._meta.pop(key, None)
        self._evict(key)

    # -- backend hooks -----------------------------------------------------
    def _store(self, key: str, payload: Any) -> float:
        raise NotImplementedError

    def _load(self, key: str) -> Tuple[Any, float]:
        raise NotImplementedError

    def _evict(self, key: str) -> None:
        pass
