"""Unified connector interface (paper §3.4).

A connector moves intermediate data objects (embeddings, hidden states,
codec tokens, audio/image tensors — and intra-stage KV / MM caches) between
stages through a common interface; only lightweight metadata rides the
control plane.

The connector surface is the channel API — ``send`` returns a
:class:`TransferHandle` immediately, ``recv`` blocks (or polls, via
``poll``) until the key has been published by the producer side, and
``release`` ends the object's lifetime explicitly.  This is what the
per-stage workers use: the router publishes on the upstream side and the
destination stage worker receives + deserializes in its own thread (or
process), overlapping transfers with compute.  A ``recv`` that waits out
its timeout raises :class:`TransferTimeout` carrying the key (and edge,
when the router attached one) so the failure is attributable per-request.

The original synchronous ``put`` / ``get`` / ``delete`` trio is
DEPRECATED (it duplicated the resident-bytes accounting path); the shims
below forward to ``send`` / ``recv`` / ``release`` and emit a
``DeprecationWarning``.  They disappear next release.

All entry points are thread-safe (one lock + condition per connector
instance: producers notify, consumers wait).

On this CPU container the three backends model the paper's deployment
topologies:
  - InlineConnector   — control-queue pass-by-reference (small payloads).
  - SharedMemoryConnector — single-node shm: payloads are serialized into a
    host buffer pool (a real copy, like /dev/shm) and deserialized on get.
  - MooncakeConnector — multi-node put/get store: serializing copy on both
    ends + a bandwidth/latency cost model for the TCP/RDMA hop.

On real TPU the payload hop is a ``jax.device_put`` onto the destination
stage's submesh (ICI/DCN); connectors count bytes either way so Table 1 can
be reproduced.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax


class TransferTimeout(TimeoutError):
    """``recv(key, timeout)`` waited out its timeout.

    Carries the ``key`` (and the ``edge`` the router attached, when the
    recv ran inside a stage worker's resolve) so the router can fail the
    one request that owns the transfer instead of killing the worker."""

    def __init__(self, key: str, *, connector: str = "?",
                 edge: Optional[str] = None,
                 timeout: Optional[float] = None):
        self.key = key
        self.connector = connector
        self.edge = edge
        self.timeout = timeout
        where = f" on edge {edge!r}" if edge else ""
        after = f" after {timeout:.3f}s" if timeout is not None else ""
        super().__init__(
            f"connector[{connector}] recv({key!r}){where} timed out{after}")

    def with_edge(self, edge: str) -> "TransferTimeout":
        return TransferTimeout(self.key, connector=self.connector,
                               edge=edge, timeout=self.timeout)


@dataclass
class TransferStats:
    calls: int = 0
    bytes: int = 0
    wall_time: float = 0.0       # measured time spent in put+get
    modeled_time: float = 0.0    # cost-model time (e.g. RDMA hop)

    def record(self, nbytes: int, wall: float, modeled: float = 0.0) -> None:
        self.calls += 1
        self.bytes += nbytes
        self.wall_time += wall
        self.modeled_time += modeled


@dataclass
class TransferHandle:
    """Returned by ``send``: enough for the control plane to route the
    object without touching the data plane."""
    key: str
    nbytes: int
    t_send: float


class Connector:
    """put/get data plane + metadata control plane + async channel API.

    Concurrency contract: the heavy data-plane hooks (``_pack`` /
    ``_unpack`` — serialize and deserialize copies) run WITHOUT the
    connector lock, so two stage workers can deserialize concurrently and
    the router's publish never waits behind an in-progress recv.  Only the
    cheap control-plane hooks (``_publish`` / ``_fetch`` / ``_evict`` —
    dict bookkeeping) run under the lock.
    """

    name = "base"

    def __init__(self) -> None:
        self.stats = TransferStats()
        self._meta: Dict[str, dict] = {}       # guarded-by: _lock
        self._entries: Dict[str, Any] = {}     # guarded-by: _lock
        self._lock = threading.RLock()
        self._ready = threading.Condition(self._lock)

    # -- control plane ---------------------------------------------------
    def metadata(self, key: str) -> Optional[dict]:
        with self._lock:
            return self._meta.get(key)

    def poll(self, key: str) -> bool:
        """True once the key has been published and not yet released."""
        with self._lock:
            return key in self._meta

    # -- async channel API -------------------------------------------------
    def send(self, key: str, payload: Any) -> TransferHandle:
        """Publish a payload under ``key`` and wake any waiting ``recv``."""
        t0 = time.perf_counter()
        nbytes = payload_nbytes(payload)
        entry, modeled = self._pack(payload)         # heavy copy, unlocked
        with self._ready:
            self._publish(key, entry)
            self._meta[key] = {"nbytes": nbytes, "t_put": t0}
            self.stats.record(nbytes, time.perf_counter() - t0, modeled)
            self._ready.notify_all()
        return TransferHandle(key=key, nbytes=nbytes, t_send=t0)

    def recv(self, key: str, timeout: Optional[float] = None) -> Any:
        """Block until ``key`` is published, then load it.

        ``timeout=None`` waits forever; ``timeout=0`` is a non-blocking
        probe. Raises ``TimeoutError`` if the key never shows up.
        """
        t0 = time.perf_counter()
        deadline = None if timeout is None else t0 + timeout
        with self._ready:
            # the while condition re-checks after every wait, so a publish
            # racing the timeout expiry is never dropped
            while key not in self._meta:
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise TransferTimeout(key, connector=self.name,
                                          timeout=timeout)
                self._ready.wait(remaining)
            entry = self._fetch(key)
        payload, modeled = self._unpack(entry)       # heavy copy, unlocked
        with self._lock:
            self.stats.wall_time += time.perf_counter() - t0
            self.stats.modeled_time += modeled
        return payload

    def release(self, key: str) -> None:
        """Explicitly end the object's lifetime (eviction)."""
        with self._lock:
            self._meta.pop(key, None)
            self._evict(key)

    # -- synchronous API (DEPRECATED shims, one release) -------------------
    def _deprecated(self, old: str, new: str) -> None:
        warnings.warn(
            f"Connector.{old}() is deprecated; use Connector.{new}() — "
            f"the send/recv/release channel API is the single surface "
            f"(and the single resident-bytes accounting path)",
            DeprecationWarning, stacklevel=3)

    def put(self, key: str, payload: Any) -> None:
        self._deprecated("put", "send")
        self.send(key, payload)

    def get(self, key: str) -> Any:
        self._deprecated("get", "recv")
        with self._ready:
            if key not in self._meta:
                raise KeyError(key)
        return self.recv(key, timeout=0.0)

    def delete(self, key: str) -> None:
        self._deprecated("delete", "release")
        self.release(key)

    # -- backend hooks -----------------------------------------------------
    # heavy data plane — run WITHOUT the connector lock, must not touch
    # shared state
    def _pack(self, payload: Any) -> Tuple[Any, float]:
        """payload -> (storable entry, modeled transfer time)."""
        return payload, 0.0

    def _unpack(self, entry: Any) -> Tuple[Any, float]:
        """stored entry -> (payload, modeled transfer time)."""
        return entry, 0.0

    # cheap control plane — run under the connector lock
    def _publish(self, key: str, entry: Any) -> None:  # requires-lock: _lock
        self._entries[key] = entry

    def _fetch(self, key: str) -> Any:  # requires-lock: _lock
        return self._entries[key]

    def _evict(self, key: str) -> None:  # requires-lock: _lock
        self._entries.pop(key, None)


def payload_nbytes(payload: Any) -> int:
    leaves = jax.tree.leaves(payload)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "nbytes"):
            total += leaf.nbytes
        elif isinstance(leaf, (bytes, bytearray)):
            total += len(leaf)
        elif isinstance(leaf, (int, float, bool)):
            total += 8
        elif isinstance(leaf, str):
            total += len(leaf)
    return total
