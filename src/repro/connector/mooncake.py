"""Mooncake-style connector: cross-node put/get object store.

Data plane: serializing copy on put and on get (two memcpys, as in a real
distributed KV store client), plus a TCP/RDMA hop cost model
(latency + bytes/bandwidth) reported as ``stats.modeled_time`` — this
container has one node, so the wire time is modeled, not slept.
Control plane: metadata only ({key, nbytes, location}), as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.connector.base import Connector, payload_nbytes


class MooncakeConnector(Connector):
    name = "mooncake"

    def __init__(self, bandwidth_gbps: float = 12.5, latency_s: float = 30e-6):
        """Defaults model 100 GbE RDMA: 12.5 GB/s, 30us one-way latency."""
        super().__init__()
        self._objects: Dict[str, tuple] = {}
        self.bandwidth = bandwidth_gbps * 1e9
        self.latency = latency_s

    def _wire_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth

    def _store(self, key: str, payload: Any) -> float:
        leaves, treedef = jax.tree.flatten(payload)
        blobs = []
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                arr = np.asarray(leaf)
                blobs.append(("arr", arr.tobytes(), arr.dtype.str, arr.shape))
            else:
                blobs.append(("py", leaf, None, None))
        self._objects[key] = (blobs, treedef)
        return self._wire_time(payload_nbytes(payload))

    def _load(self, key: str) -> Tuple[Any, float]:
        blobs, treedef = self._objects[key]
        leaves = []
        nbytes = 0
        for kind, data, dtype, shape in blobs:
            if kind == "arr":
                leaves.append(np.frombuffer(data, dtype=dtype).reshape(shape))
                nbytes += len(data)
            else:
                leaves.append(data)
        return jax.tree.unflatten(treedef, leaves), self._wire_time(nbytes)

    def _evict(self, key: str) -> None:
        self._objects.pop(key, None)


def make_connector(name: str, **kw) -> Connector:
    from repro.connector.inline import InlineConnector
    from repro.connector.shm import SharedMemoryConnector
    if name == "inline":
        return InlineConnector()
    if name == "shm":
        return SharedMemoryConnector()
    if name == "mooncake":
        return MooncakeConnector(**kw)
    raise ValueError(f"unknown connector {name!r}")
