"""Mooncake-style connector: cross-node put/get object store.

Data plane: serializing copy on put and on get (two memcpys, as in a real
distributed KV store client), plus a TCP/RDMA hop cost model
(latency + bytes/bandwidth) reported as ``stats.modeled_time`` — this
container has one node, so the wire time is modeled, not slept.  Both
copies run outside the connector lock (``_pack``/``_unpack``).
Control plane: metadata only ({key, nbytes, location}), as in the paper.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np

from repro.connector.base import Connector


class MooncakeConnector(Connector):
    name = "mooncake"

    def __init__(self, bandwidth_gbps: float = 12.5, latency_s: float = 30e-6):
        """Defaults model 100 GbE RDMA: 12.5 GB/s, 30us one-way latency."""
        super().__init__()
        self.bandwidth = bandwidth_gbps * 1e9
        self.latency = latency_s
        # store-side occupancy: objects published but not yet released
        # (the channel API makes lifetimes explicit, so this is auditable)
        self.resident_objects = 0              # guarded-by: _lock
        self.peak_resident_objects = 0         # guarded-by: _lock

    def _wire_time(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth

    def _pack(self, payload: Any) -> Tuple[Any, float]:
        leaves, treedef = jax.tree.flatten(payload)
        blobs = []
        nbytes = 0
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                arr = np.asarray(leaf)
                raw = arr.tobytes()
                nbytes += len(raw)
                blobs.append(("arr", raw, arr.dtype.str, arr.shape))
            else:
                blobs.append(("py", leaf, None, None))
        return (blobs, treedef, nbytes), self._wire_time(nbytes)

    def _unpack(self, entry: Any) -> Tuple[Any, float]:
        blobs, treedef, nbytes = entry
        leaves = []
        for kind, data, dtype, shape in blobs:
            if kind == "arr":
                leaves.append(np.frombuffer(data, dtype=dtype).reshape(shape))
            else:
                leaves.append(data)
        return jax.tree.unflatten(treedef, leaves), self._wire_time(nbytes)

    def _publish(self, key: str, entry: Any) -> None:  # requires-lock: _lock
        if key not in self._entries:
            self.resident_objects += 1
            self.peak_resident_objects = max(self.peak_resident_objects,
                                             self.resident_objects)
        self._entries[key] = entry

    def _evict(self, key: str) -> None:  # requires-lock: _lock
        if self._entries.pop(key, None) is not None:
            self.resident_objects -= 1


def make_connector(name: str, **kw) -> Connector:
    from repro.connector.inline import InlineConnector
    from repro.connector.shm import SharedMemoryConnector
    if name == "inline":
        return InlineConnector()
    if name == "shm":
        return SharedMemoryConnector()
    if name == "mooncake":
        return MooncakeConnector(**kw)
    raise ValueError(f"unknown connector {name!r}")
