"""Shared-memory connector: single-node large-payload transport.

Payloads are flattened to contiguous host buffers (a real serialize copy —
the analogue of writing into /dev/shm) and reconstructed on get.  Both
copies run outside the connector lock (``_pack``/``_unpack``), so
concurrent stage workers deserialize in parallel.  The pool tracks
resident bytes and a high-water mark so the explicit-lifetime channel API
(``send``/``recv``/``release``) can be audited for leaks: a serving run
that never releases its keys shows up as a monotonically growing
``resident_bytes``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np

from repro.connector.base import Connector


class SharedMemoryConnector(Connector):
    name = "shm"

    def __init__(self) -> None:
        super().__init__()
        self.resident_bytes = 0
        self.peak_resident_bytes = 0

    def _pack(self, payload: Any) -> Tuple[Any, float]:
        leaves, treedef = jax.tree.flatten(payload)
        bufs = []
        nbytes = 0
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                arr = np.asarray(leaf)
                raw = arr.tobytes()
                nbytes += len(raw)
                bufs.append(("arr", raw, arr.dtype.str, arr.shape))
            else:
                bufs.append(("py", leaf, None, None))
        return (bufs, treedef, nbytes), 0.0

    def _unpack(self, entry: Any) -> Tuple[Any, float]:
        bufs, treedef, _ = entry
        leaves = []
        for kind, data, dtype, shape in bufs:
            if kind == "arr":
                leaves.append(np.frombuffer(data, dtype=dtype).reshape(shape))
            else:
                leaves.append(data)
        return jax.tree.unflatten(treedef, leaves), 0.0

    def _publish(self, key: str, entry: Any) -> None:
        if key in self._entries:
            self._evict(key)
        self._entries[key] = entry
        self.resident_bytes += entry[2]
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes)

    def _evict(self, key: str) -> None:
        entry = self._entries.pop(key, None)
        if entry is not None:
            self.resident_bytes -= entry[2]
