"""Shared-memory connector: single-node large-payload transport.

Payloads are flattened to contiguous host buffers (a real serialize copy —
the analogue of writing into /dev/shm) and reconstructed on get.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.connector.base import Connector


class SharedMemoryConnector(Connector):
    name = "shm"

    def __init__(self) -> None:
        super().__init__()
        self._buffers: Dict[str, tuple] = {}

    def _store(self, key: str, payload: Any) -> float:
        leaves, treedef = jax.tree.flatten(payload)
        bufs = []
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                arr = np.asarray(leaf)
                bufs.append(("arr", arr.tobytes(), arr.dtype.str, arr.shape))
            else:
                bufs.append(("py", leaf, None, None))
        self._buffers[key] = (bufs, treedef)
        return 0.0

    def _load(self, key: str) -> Tuple[Any, float]:
        bufs, treedef = self._buffers[key]
        leaves = []
        for kind, data, dtype, shape in bufs:
            if kind == "arr":
                leaves.append(np.frombuffer(data, dtype=dtype).reshape(shape))
            else:
                leaves.append(data)
        return jax.tree.unflatten(treedef, leaves), 0.0

    def _evict(self, key: str) -> None:
        self._buffers.pop(key, None)
