"""Shared-memory connector: single-node large-payload transport.

Two data planes share the same channel API and resident accounting:

  - in-process (default): payloads are flattened to contiguous host
    buffers (a real serialize copy — the analogue of writing into
    /dev/shm) and reconstructed on recv.
  - ``cross_process=True``: payloads are written into **named**
    ``multiprocessing.shared_memory`` segments via
    :mod:`repro.connector.shm_transport`.  ``recv`` in the publishing
    process attaches the same segment; a *different* process receives by
    shipping the picklable :meth:`manifest` over a control channel and
    calling :func:`shm_transport.read_manifest` — this is how process
    stage replicas and the warm-seed transport move tensors across the
    spawn boundary.  ``release`` unlinks the segment.

Both serialize/deserialize copies run outside the connector lock
(``_pack``/``_unpack``), so concurrent stage workers move data in
parallel.  The pool tracks resident bytes and a high-water mark so the
explicit-lifetime channel API (``send``/``recv``/``release``) can be
audited for leaks: a serving run that never releases its keys shows up
as a monotonically growing ``resident_bytes`` (and, cross-process, as
orphaned /dev/shm segments).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import numpy as np

from repro.connector import shm_transport
from repro.connector.base import Connector
from repro.connector.shm_transport import SegmentManifest


@dataclass
class _SegEntry:
    """A published cross-process payload: the creator's live mapping (for
    same-process recv + unlink) and the shippable manifest."""
    seg: Any
    manifest: SegmentManifest


class SharedMemoryConnector(Connector):
    name = "shm"

    def __init__(self, cross_process: bool = False) -> None:
        super().__init__()
        if cross_process and not shm_transport.available():
            raise RuntimeError(
                "cross_process=True needs multiprocessing.shared_memory")
        self.cross_process = cross_process
        self.resident_bytes = 0                # guarded-by: _lock
        self.peak_resident_bytes = 0           # guarded-by: _lock

    # -- data plane (runs without the connector lock) ----------------------
    def _pack(self, payload: Any) -> Tuple[Any, float]:
        if self.cross_process:
            seg, manifest = shm_transport.write_segment(payload)
            return _SegEntry(seg, manifest), 0.0
        leaves, treedef = jax.tree.flatten(payload)
        bufs = []
        nbytes = 0
        for leaf in leaves:
            if hasattr(leaf, "shape"):
                arr = np.asarray(leaf)
                raw = arr.tobytes()
                nbytes += len(raw)
                bufs.append(("arr", raw, arr.dtype.str, arr.shape))
            else:
                bufs.append(("py", leaf, None, None))
        return (bufs, treedef, nbytes), 0.0

    def _unpack(self, entry: Any) -> Tuple[Any, float]:
        if isinstance(entry, _SegEntry):
            return shm_transport.read_manifest(entry.manifest), 0.0
        bufs, treedef, _ = entry
        leaves = []
        for kind, data, dtype, shape in bufs:
            if kind == "arr":
                leaves.append(np.frombuffer(data, dtype=dtype).reshape(shape))
            else:
                leaves.append(data)
        return jax.tree.unflatten(treedef, leaves), 0.0

    # -- cross-process control plane ---------------------------------------
    def manifest(self, key: str) -> SegmentManifest:
        """Picklable descriptor of a published key for a receiver in
        ANOTHER process (``shm_transport.read_manifest`` rebuilds the
        payload there).  The publisher still owns the lifetime: call
        ``release(key)`` here once the remote side confirmed receipt."""
        with self._lock:
            entry = self._entries[key]
        if not isinstance(entry, _SegEntry):
            raise RuntimeError(
                f"connector[shm] key {key!r} was published in-process; "
                f"construct SharedMemoryConnector(cross_process=True) "
                f"to export manifests")
        return entry.manifest

    # -- bookkeeping (runs under the connector lock) -----------------------
    @staticmethod
    def _entry_nbytes(entry: Any) -> int:
        return (entry.manifest.nbytes if isinstance(entry, _SegEntry)
                else entry[2])

    def _publish(self, key: str, entry: Any) -> None:  # requires-lock: _lock
        if key in self._entries:
            self._evict(key)
        self._entries[key] = entry
        self.resident_bytes += self._entry_nbytes(entry)
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes)

    def _evict(self, key: str) -> None:  # requires-lock: _lock
        entry = self._entries.pop(key, None)
        if entry is None:
            return
        self.resident_bytes -= self._entry_nbytes(entry)
        if isinstance(entry, _SegEntry) and entry.seg is not None:
            try:
                entry.seg.close()
                entry.seg.unlink()
            except FileNotFoundError:    # remote side released it first
                pass
