"""Named shared-memory data plane for cross-process transfers.

This is the transport that promotes the shm connector (and the process
stage workers built on it) from "host-buffer copy inside one address
space" to a genuinely cross-process hop: array payloads are written into
one named ``multiprocessing.shared_memory`` segment, and a small
picklable *manifest* (segment name + per-array slot layout + the
non-array skeleton of the payload) travels over the control channel —
a queue, pipe, or any other metadata path.  The receiving process
attaches the segment by name, copies the arrays out, and reconstructs
the payload; the creator (or anyone holding the manifest) unlinks the
segment to end its lifetime.

Deliberately import-light: numpy only, no jax — spawned worker children
attach manifests without paying the jax import.  Payload structure is
flattened with a small pure-python walk over dict/list/tuple containers
(everything the in-repo payloads use); non-array leaves ride inside the
manifest itself and are pickled by whatever carries it.
"""
from __future__ import annotations

import sys
from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

try:                                     # unavailable on exotic platforms
    from multiprocessing import shared_memory as _shm
except ImportError:                      # pragma: no cover
    _shm = None


def available() -> bool:
    """True when named shared-memory segments can be created here."""
    return _shm is not None


@dataclass
class _ArrRef:
    """Marker inside a skeleton: leaf lives in segment slot ``i``."""
    i: int


@dataclass
class SegmentManifest:
    """Everything a *different process* needs to rebuild the payload.

    Picklable; ship it over any control channel.  ``slots`` are
    ``(dtype_str, shape, offset, size)`` views into the named segment;
    ``skeleton`` is the payload structure with arrays replaced by
    :class:`_ArrRef` markers and all other leaves inline.
    """
    segment: Optional[str]               # None: no arrays, skeleton-only
    nbytes: int
    slots: List[Tuple[str, tuple, int, int]] = field(default_factory=list)
    skeleton: Any = None


def _flatten(obj: Any, arrays: List[np.ndarray]) -> Any:
    """Payload -> skeleton; array leaves appended to ``arrays``."""
    if isinstance(obj, dict):
        return {k: _flatten(v, arrays) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        flat = [_flatten(v, arrays) for v in obj]
        return flat if isinstance(obj, list) else tuple(flat)
    if hasattr(obj, "shape") and hasattr(obj, "dtype"):
        arrays.append(np.ascontiguousarray(np.asarray(obj)))
        return _ArrRef(len(arrays) - 1)
    return obj


def _unflatten(skel: Any, leaves: List[np.ndarray]) -> Any:
    if isinstance(skel, dict):
        return {k: _unflatten(v, leaves) for k, v in skel.items()}
    if isinstance(skel, (list, tuple)):
        flat = [_unflatten(v, leaves) for v in skel]
        return flat if isinstance(skel, list) else tuple(flat)
    if isinstance(skel, _ArrRef):
        return leaves[skel.i]
    return skel


def write_segment(payload: Any) -> Tuple[Optional[Any], SegmentManifest]:
    """Serialize ``payload`` into one named segment.

    Returns ``(shm, manifest)``; ``shm`` (kept by the creator for
    lifetime control) is None when the payload holds no arrays — the
    manifest alone carries it.
    """
    if _shm is None:
        raise RuntimeError("shared_memory unavailable on this platform")
    arrays: List[np.ndarray] = []
    skeleton = _flatten(payload, arrays)
    slots: List[Tuple[str, tuple, int, int]] = []
    offset = 0
    for a in arrays:
        slots.append((a.dtype.str, tuple(a.shape), offset, a.nbytes))
        offset += a.nbytes
    if not arrays or offset == 0:
        # no array bytes to share — but keep slot metadata so zero-size
        # arrays still rebuild with their dtype/shape
        return None, SegmentManifest(segment=None, nbytes=0, slots=slots,
                                     skeleton=skeleton)
    seg = _shm.SharedMemory(create=True, size=offset)
    for a, (_, _, off, size) in zip(arrays, slots):
        seg.buf[off:off + size] = a.tobytes()
    return seg, SegmentManifest(segment=seg.name, nbytes=offset,
                                slots=slots, skeleton=skeleton)


def _attach(name: str):
    """Attach an existing segment for a READ that does not adopt
    ownership.

    Tracker bookkeeping: spawned children inherit the parent's resource
    tracker (one shared cache for the whole process tree), so a segment
    is registered exactly once at create and unregistered exactly once
    at unlink — whichever process performs them.  A pre-3.13 attach
    re-registers the name, which is a harmless set no-op on the shared
    tracker; explicitly unregistering here (the classic "attach
    workaround") would instead drop the creator's live registration and
    make the eventual unlink crash the tracker.  3.13+ can say what it
    means with ``track=False``."""
    if sys.version_info >= (3, 13):      # track= landed in 3.13
        return _shm.SharedMemory(name=name, track=False)
    return _shm.SharedMemory(name=name)


def read_manifest(manifest: SegmentManifest) -> Any:
    """Rebuild the payload in THIS process (copying arrays out, so the
    result outlives the segment)."""
    leaves: List[np.ndarray] = []
    if manifest.segment is None:
        for dtype, shape, _, _ in manifest.slots:
            leaves.append(np.empty(shape, dtype=np.dtype(dtype)))
        return _unflatten(manifest.skeleton, leaves)
    seg = _attach(manifest.segment)
    try:
        for dtype, shape, off, size in manifest.slots:
            raw = bytes(seg.buf[off:off + size])
            leaves.append(np.frombuffer(raw, dtype=np.dtype(dtype))
                          .reshape(shape))
    finally:
        seg.close()
    return _unflatten(manifest.skeleton, leaves)


def release_manifest(manifest: SegmentManifest) -> None:
    """End the segment's lifetime from any process holding the manifest
    (idempotent: an already-unlinked segment is fine)."""
    if manifest.segment is None:
        return
    try:
        # plain (tracked) attach on purpose: unlink() below unregisters
        # the name from the process tree's shared resource tracker, so
        # the create-time registration balances no matter which process
        # performs the release
        seg = _shm.SharedMemory(name=manifest.segment)
    except FileNotFoundError:
        return
    try:
        seg.close()
        seg.unlink()
    except FileNotFoundError:            # pragma: no cover — racing release
        pass


# -- send/recv over a queue-like control channel ----------------------------

def ship(channel_put, payload: Any) -> None:
    """Write ``payload`` to a segment and put its manifest on a control
    channel (``channel_put`` is e.g. ``mp.Queue.put``).  Ownership of the
    segment passes to the receiver: the creator closes its mapping but
    does not unlink — ``read_and_release`` on the other side does."""
    seg, manifest = write_segment(payload)
    if seg is not None:
        seg.close()                      # tracker entry cleared at unlink
    channel_put(manifest)


def read_and_release(manifest: SegmentManifest) -> Any:
    """Receiver side of :func:`ship`: rebuild, then unlink."""
    try:
        return read_manifest(manifest)
    finally:
        release_manifest(manifest)
