"""Diffusion (DiT) stage engine + encode/custom engines (paper §3.3).

DiffusionEngine: per-stage request batching for DiT denoising. Requests
with the same output length bucket are batched and denoised together
(rectified-flow Euler); TeaCache-style velocity reuse via cache_interval.
Streaming inputs: a request whose condition arrives in chunks can be
configured chunk-wise (each chunk is synthesized independently — the
Qwen-Omni vocoder pattern) so synthesis overlaps upstream decoding.

EncodeEngine: batched single-forward stages (multimodal encoders — the
paper's footnote-3 'encoder as separate stage' case).

CustomEngine: arbitrary jitted callables (e.g. the CNN vocoder of
Qwen3-Omni or MiMo-Audio's patch decoder).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.request import StageEvent
from repro.models.dit import DiTConfig, sample as dit_sample


@dataclass(eq=False)              # identity equality: the generated eq
class _DiffJob:                   # would elementwise-compare cond arrays
    req_id: int                   # (and raise on mismatched chunk shapes
    cond: np.ndarray              # (Tc, cond_dim)    in queue.remove)
    out_len: int
    chunk_index: int = 0
    is_last_chunk: bool = True


class DiffusionEngine:
    def __init__(self, name: str, cfg: DiTConfig, params, *,
                 max_batch: int = 4, num_steps: Optional[int] = None,
                 cache_interval: int = 1, out_len_per_cond: float = 1.0,
                 seed: int = 0):
        self.name = name
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.num_steps = num_steps or cfg.num_steps
        self.cache_interval = cache_interval
        self.out_len_per_cond = out_len_per_cond
        self.queue: List[_DiffJob] = []
        self._key = jax.random.PRNGKey(seed)
        self._sample_cache: Dict[tuple, Callable] = {}
        self.steps = 0
        self.busy_time = 0.0

    def enqueue(self, req_id: int, inputs: Dict[str, Any], sampling=None,
                data=None) -> None:
        cond = np.asarray(inputs["cond"])
        out_len = int(inputs.get("out_len",
                                 max(1, int(cond.shape[0]
                                            * self.out_len_per_cond))))
        self.queue.append(_DiffJob(
            req_id, cond, out_len,
            chunk_index=int(inputs.get("chunk_index", 0)),
            is_last_chunk=bool(inputs.get("is_last_chunk", True))))

    @property
    def has_work(self) -> bool:
        return bool(self.queue)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def _sampler(self, cond_len: int, out_len: int):
        key = (cond_len, out_len)
        if key not in self._sample_cache:
            cfg, steps, ci = self.cfg, self.num_steps, self.cache_interval

            def fn(p, cond, k):
                return dit_sample(cfg, p, cond, out_len, k, num_steps=steps,
                                  cache_interval=ci)
            self._sample_cache[key] = jax.jit(fn)
        return self._sample_cache[key]

    def step(self) -> List[StageEvent]:
        events: List[StageEvent] = []
        if not self.queue:
            return events
        t0 = time.perf_counter()
        self.steps += 1
        # bucket by (cond_len, out_len); batch the largest bucket
        buckets: Dict[tuple, List[_DiffJob]] = {}
        for job in self.queue:
            buckets.setdefault((job.cond.shape[0], job.out_len),
                               []).append(job)
        key_, jobs = max(buckets.items(), key=lambda kv: len(kv[1]))
        jobs = jobs[:self.max_batch]
        for j in jobs:
            self.queue.remove(j)
        # pad the batch to max_batch so the jitted sampler sees ONE batch
        # shape (the XLA-graph analogue of CUDA-graph static batching)
        conds = [j.cond for j in jobs]
        while len(conds) < self.max_batch:
            conds.append(np.zeros_like(conds[0]))
        cond = jnp.asarray(np.stack(conds))
        self._key, sk = jax.random.split(self._key)
        out = np.asarray(self._sampler(*key_)(self.params, cond, sk))
        for i, j in enumerate(jobs):
            single_shot = j.is_last_chunk and j.chunk_index == 0
            events.append(StageEvent(
                j.req_id, "finished" if single_shot else "chunk",
                {"latent": out[i], "chunk_index": j.chunk_index},
                stage=self.name, chunk_index=j.chunk_index,
                is_last=j.is_last_chunk))
        self.busy_time += time.perf_counter() - t0
        return events


class EncodeEngine:
    """Batched encoder stage (one forward per request batch)."""

    def __init__(self, name: str, forward: Callable, *, max_batch: int = 8):
        self.name = name
        self.forward = forward            # forward(inputs_batch) -> outputs
        self.max_batch = max_batch
        self.queue: List[tuple] = []
        self.steps = 0
        self.busy_time = 0.0

    def enqueue(self, req_id, inputs, sampling=None, data=None) -> None:
        self.queue.append((req_id, inputs))

    @property
    def has_work(self) -> bool:
        return bool(self.queue)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def step(self) -> List[StageEvent]:
        events: List[StageEvent] = []
        if not self.queue:
            return events
        t0 = time.perf_counter()
        self.steps += 1
        batch, self.queue = (self.queue[:self.max_batch],
                             self.queue[self.max_batch:])
        outs = self.forward([inp for _, inp in batch])
        for (rid, inp), out in zip(batch, outs):
            ci = int(inp.get("chunk_index", 0))
            last = bool(inp.get("is_last_chunk", True))
            single_shot = last and ci == 0
            events.append(StageEvent(
                rid, "finished" if single_shot else "chunk", out,
                stage=self.name, chunk_index=ci, is_last=last))
        self.busy_time += time.perf_counter() - t0
        return events


class CustomEngine(EncodeEngine):
    """Arbitrary per-batch callable stage (CNN vocoder, patch codecs...)."""
