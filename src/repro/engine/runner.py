"""Model runners: the jitted step functions one AR engine executes.

PagedRunner (dense / moe / vlm stages):
  - ``prefill_chunk``: process C prompt tokens of ONE request, writing their
    K/V into the request's pages and attending over all its history pages
    (chunked prefill, Sarathi-style).
  - ``decode``: batched one-token step for ALL active slots against the
    shared page pool (vLLM-style paged attention).

StateRunner (ssm / hybrid stages): constant-size recurrent state per slot
(+ dense KV for the hybrid's shared-attention sites), reusing the
transformer's prefill/decode paths.

Both runners return final-layer hidden states so stage-transfer functions
can forward them downstream (e.g. Thinker hidden states → Talker).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.kv_cache import PagedKVConfig, init_kv_pages
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import transformer as T


def _mlp_or_moe(cfg, lp, h):
    if cfg.is_moe:
        y, _ = moe_mod.moe_forward(cfg, lp["moe"], h)
        return y
    return L.mlp(lp["mlp"], h)


class PagedRunner:
    """Paged-KV execution for attention architectures."""

    def __init__(self, cfg: ModelConfig, params, kv: PagedKVConfig):
        assert cfg.arch_type in ("dense", "moe", "vlm", "audio")
        self.cfg = cfg
        self.params = params
        self.kv = kv
        self.quant = cfg.kv_cache_dtype == "int8"
        self.k_pages, self.v_pages = init_kv_pages(cfg, kv, cfg.num_layers)
        if self.quant:
            from repro.engine.kv_cache import init_kv_scale_pages
            self.k_scales, self.v_scales = init_kv_scale_pages(
                cfg, kv, cfg.num_layers)
        else:
            self.k_scales = self.v_scales = None
        self._prefill_jit = jax.jit(
            self._prefill_impl, donate_argnums=(1, 2),
            static_argnames=())
        self._decode_jit = jax.jit(self._decode_impl, donate_argnums=(1, 2))
        # host-side copy of the embedding table: avoids retracing an eager
        # gather for every prompt length (hot path for token->embed lookups)
        self._embed_np = np.asarray(params["embed"], np.float32)

    # ---- embeds ---------------------------------------------------------
    def embed(self, tokens: np.ndarray) -> np.ndarray:
        return self._embed_np[np.asarray(tokens)]

    # ---- prefill chunk ---------------------------------------------------
    def _prefill_impl(self, params, k_pages, v_pages, k_scales, v_scales,
                      embeds, block_table, start, valid_len):
        """embeds: (1, C, d); block_table: (pp,); start, valid_len: scalars.
        Returns (logits (C,V), hidden (C,d), new page pools...)."""
        cfg = self.cfg
        c = embeds.shape[1]
        page = self.kv.page_size
        positions = start + jnp.arange(c)[None, :]            # (1, C)
        window = cfg.sliding_window if cfg.attn_variant == "swa" else 0

        pos_flat = start + jnp.arange(c)
        pid = jnp.where(pos_flat < start + valid_len,
                        block_table[pos_flat // page],
                        self.kv.num_pages)                    # OOB => dropped
        slot = pos_flat % page

        def body(h, xs):
            lp, kp, vp, ksp, vsp = xs
            hn = L.rmsnorm(lp["ln1"], h, cfg.rmsnorm_eps)
            q, k, v = L._qkv(cfg, lp["attn"], hn)
            if cfg.rope_theta:
                q = L.rope(q, positions, cfg.rope_theta)
                k = L.rope(k, positions, cfg.rope_theta)
            if self.quant:
                kq, ks = L.quantize_kv(k)
                vq, vs = L.quantize_kv(v)
                kp = kp.at[pid, slot].set(kq[0], mode="drop")
                vp = vp.at[pid, slot].set(vq[0], mode="drop")
                ksp = ksp.at[pid, slot].set(ks[0], mode="drop")
                vsp = vsp.at[pid, slot].set(vs[0], mode="drop")
                k_all = (kp[block_table].astype(jnp.float32)
                         * ksp[block_table].astype(jnp.float32)[..., None])
                v_all = (vp[block_table].astype(jnp.float32)
                         * vsp[block_table].astype(jnp.float32)[..., None])
                k_all = k_all.astype(h.dtype)
                v_all = v_all.astype(h.dtype)
            else:
                kp = kp.at[pid, slot].set(k[0], mode="drop")
                vp = vp.at[pid, slot].set(v[0], mode="drop")
                k_all, v_all = kp[block_table], vp[block_table]
            k_all = k_all.reshape(1, -1, cfg.num_kv_heads, cfg.head_dim)
            v_all = v_all.reshape(1, -1, cfg.num_kv_heads, cfg.head_dim)
            o = ref.chunk_attention(q, k_all, v_all, start, window=window)
            h = h + jnp.einsum("bsqh,qhd->bsd", o, lp["attn"]["wo"])
            hn = L.rmsnorm(lp["ln2"], h, cfg.rmsnorm_eps)
            h = h + _mlp_or_moe(cfg, lp, hn)
            return h, (kp, vp, ksp, vsp)

        scales = ((k_scales, v_scales) if self.quant else (None, None))
        h, (k_pages, v_pages, k_scales, v_scales) = jax.lax.scan(
            body, embeds, (params["blocks"], k_pages, v_pages, *scales))
        hidden = h[0]
        logits = T._unembed(cfg, params, h)[0]
        return logits, hidden, k_pages, v_pages, k_scales, v_scales

    def prefill_chunk(self, embeds, block_table, start, valid_len):
        (logits, hidden, self.k_pages, self.v_pages, self.k_scales,
         self.v_scales) = self._prefill_jit(
            self.params, self.k_pages, self.v_pages, self.k_scales,
            self.v_scales, embeds,
            jnp.asarray(block_table), jnp.asarray(start, jnp.int32),
            jnp.asarray(valid_len, jnp.int32))
        return logits, hidden

    # ---- prefix cache: copy-on-write page copies -------------------------
    @functools.partial(jax.jit, static_argnums=0, donate_argnums=(1, 2))
    def _copy_pages_jit(self, k_pages, v_pages, src, dst):
        return (k_pages.at[:, dst].set(k_pages[:, src]),
                v_pages.at[:, dst].set(v_pages[:, src]))

    def copy_pages(self, src_pages, dst_pages) -> None:
        """Copy whole KV pages across all layers (copy-on-write: a request
        extending a shared cached page gets a private copy first).  One
        jitted donated call per pool pair — the update happens in place
        instead of materializing a full pool copy per eager ``.at.set``
        (this runs at admission, so it is on the TTFT path)."""
        src = jnp.asarray(np.asarray(src_pages, np.int32))
        dst = jnp.asarray(np.asarray(dst_pages, np.int32))
        self.k_pages, self.v_pages = self._copy_pages_jit(
            self.k_pages, self.v_pages, src, dst)
        if self.quant:
            self.k_scales, self.v_scales = self._copy_pages_jit(
                self.k_scales, self.v_scales, src, dst)

    # ---- PD disaggregation: KV extraction / injection -------------------
    def extract_kv(self, block_table, n_tokens: int):
        """Pull one request's prompt KV out of the page pool.

        Returns (k, v): (L, n_pages*page, nkv, hd) host arrays (trailing
        padding past n_tokens is zeros) — the payload a prefill stage ships
        to a decode stage through the unified connector.
        """
        page = self.kv.page_size
        n_pages = -(-n_tokens // page)
        bt = jnp.asarray(block_table[:n_pages])
        k = self.k_pages[:, bt]
        v = self.v_pages[:, bt]
        if self.quant:
            # ship full-precision KV (the receiving stage re-quantizes)
            k = k.astype(jnp.float32) * self.k_scales[:, bt][..., None]
            v = v.astype(jnp.float32) * self.v_scales[:, bt][..., None]
        shape = (self.cfg.num_layers, n_pages * page,
                 self.cfg.num_kv_heads, self.cfg.head_dim)
        return np.asarray(k.reshape(shape)), np.asarray(v.reshape(shape))

    def inject_kv(self, k_seed, v_seed, block_table, n_tokens: int) -> None:
        """Write transferred prompt KV into this engine's page pool."""
        page = self.kv.page_size
        n_pages = -(-n_tokens // page)
        pad = n_pages * page - k_seed.shape[1]
        if pad:
            padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
            k_seed = np.pad(k_seed, padw)
            v_seed = np.pad(v_seed, padw)
        Ln, _, nkv, hd = k_seed.shape
        kp = jnp.asarray(k_seed.reshape(Ln, n_pages, page, nkv, hd))
        vp = jnp.asarray(v_seed.reshape(Ln, n_pages, page, nkv, hd))
        bt = jnp.asarray(block_table[:n_pages])
        if self.quant:
            from repro.models.layers import quantize_kv
            kq, ks = quantize_kv(kp)
            vq, vs = quantize_kv(vp)
            self.k_pages = self.k_pages.at[:, bt].set(kq)
            self.v_pages = self.v_pages.at[:, bt].set(vq)
            self.k_scales = self.k_scales.at[:, bt].set(ks)
            self.v_scales = self.v_scales.at[:, bt].set(vs)
        else:
            self.k_pages = self.k_pages.at[:, bt].set(kp.astype(
                self.k_pages.dtype))
            self.v_pages = self.v_pages.at[:, bt].set(vp.astype(
                self.v_pages.dtype))

    # ---- batched decode ---------------------------------------------------
    def _decode_impl(self, params, k_pages, v_pages, k_scales, v_scales,
                     embeds, block_tables, positions, active):
        """embeds: (B,1,d); block_tables: (B,pp); positions: (B,) current
        token's write position; active: (B,) bool.
        Returns (logits (B,V), hidden (B,d), new page pools...)."""
        cfg = self.cfg
        page = self.kv.page_size
        window = cfg.sliding_window if cfg.attn_variant == "swa" else 0
        bidx = jnp.arange(embeds.shape[0])
        pid = jnp.where(active, block_tables[bidx, positions // page],
                        self.kv.num_pages)
        slot = positions % page
        seq_lens = jnp.where(active, positions + 1, 0)

        def body(h, xs):
            lp, kp, vp, ksp, vsp = xs
            hn = L.rmsnorm(lp["ln1"], h, cfg.rmsnorm_eps)
            q, k, v = L._qkv(cfg, lp["attn"], hn)
            if cfg.rope_theta:
                q = L.rope(q, positions[:, None], cfg.rope_theta)
                k = L.rope(k, positions[:, None], cfg.rope_theta)
            if self.quant:
                kq, ks = L.quantize_kv(k)
                vq, vs = L.quantize_kv(v)
                kp = kp.at[pid, slot].set(kq[:, 0], mode="drop")
                vp = vp.at[pid, slot].set(vq[:, 0], mode="drop")
                ksp = ksp.at[pid, slot].set(ks[:, 0], mode="drop")
                vsp = vsp.at[pid, slot].set(vs[:, 0], mode="drop")
            else:
                kp = kp.at[pid, slot].set(k[:, 0], mode="drop")
                vp = vp.at[pid, slot].set(v[:, 0], mode="drop")
            o = ops.paged_attention(q[:, 0], kp, vp, block_tables, seq_lens,
                                    window=window, k_scale_pages=ksp,
                                    v_scale_pages=vsp)
            h = h + jnp.einsum("bqh,qhd->bd", o.astype(h.dtype),
                               lp["attn"]["wo"])[:, None]
            hn = L.rmsnorm(lp["ln2"], h, cfg.rmsnorm_eps)
            h = h + _mlp_or_moe(cfg, lp, hn)
            return h, (kp, vp, ksp, vsp)

        scales = ((k_scales, v_scales) if self.quant else (None, None))
        h, (k_pages, v_pages, k_scales, v_scales) = jax.lax.scan(
            body, embeds, (params["blocks"], k_pages, v_pages, *scales))
        hidden = h[:, 0]
        logits = T._unembed(cfg, params, h)[:, 0]
        return logits, hidden, k_pages, v_pages, k_scales, v_scales

    def decode(self, embeds, block_tables, positions, active):
        (logits, hidden, self.k_pages, self.v_pages, self.k_scales,
         self.v_scales) = self._decode_jit(
            self.params, self.k_pages, self.v_pages, self.k_scales,
            self.v_scales, embeds,
            jnp.asarray(block_tables), jnp.asarray(positions),
            jnp.asarray(active))
        return logits, hidden


class StateRunner:
    """Recurrent-state execution for SSM / hybrid architectures.

    Slots share batched state arrays; prefill is a single scan per request
    (SSM prefill has no chunking — the scan IS the prefill), decode is a
    batched one-token step.
    """

    def __init__(self, cfg: ModelConfig, params, kv: PagedKVConfig,
                 max_batch: int):
        assert cfg.arch_type in ("ssm", "hybrid")
        self.cfg = cfg
        self.params = params
        self.kv = kv
        self.max_batch = max_batch
        self.cache = T.init_decode_cache(cfg, max_batch, kv.max_seq)
        self._prefill_jit = jax.jit(self._prefill_impl)
        self._insert_jit = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._decode_jit = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._embed_np = np.asarray(params["embed"], np.float32)

    def embed(self, tokens: np.ndarray) -> np.ndarray:
        return self._embed_np[np.asarray(tokens)]

    def _prefill_impl(self, params, embeds):
        cfg = self.cfg
        # reuse transformer prefill on a batch of 1
        logits, cache1 = _prefill_from_embeds(cfg, params, embeds,
                                              self.kv.max_seq)
        hidden = None
        return logits[0], cache1

    def _insert_impl(self, cache, cache1, slot):
        def ins(c, c1):
            return c.at[:, slot].set(c1[:, 0])
        return jax.tree.map(ins, cache, cache1)

    def prefill(self, embeds, slot):
        logits, cache1 = self._prefill_jit(self.params, embeds)
        self.cache = self._insert_jit(self.cache, cache1, slot)
        return logits, None

    def _decode_impl(self, params, cache, embeds, positions, active):
        cfg = self.cfg
        logits, new_cache = _decode_from_embeds(cfg, params, cache, embeds,
                                                positions)
        # inactive slots must be a no-op: without the mask they run the
        # step anyway and write stale-position state/KV into the shared
        # cache (every leaf is (outer, batch, ...), batch at dim 1)
        def _sel(new, old):
            mask = active.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(mask, new, old)

        cache = jax.tree.map(_sel, new_cache, cache)
        return logits[:, 0], cache

    def decode(self, embeds, block_tables, positions, active):
        logits, self.cache = self._decode_jit(
            self.params, self.cache, embeds, jnp.asarray(positions),
            jnp.asarray(active))
        return logits, None


# ---- embed-level wrappers around transformer.py (prompts may be embeds) ----

def _prefill_from_embeds(cfg, params, embeds, max_seq):
    """transformer.forward_prefill but starting from embeddings
    (treat inputs as precomputed frames so _embed passes them through)."""
    cfg2 = cfg.replace(modality="audio_frames")
    return T.forward_prefill(cfg2, params, embeds, max_seq, remat=False)


def _decode_from_embeds(cfg, params, cache, embeds, positions):
    cfg2 = cfg.replace(modality="audio_frames")
    return T.forward_decode(cfg2, params, cache, embeds, positions)
