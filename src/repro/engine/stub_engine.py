"""Deterministic stub stage engine (jax-free).

One item per ``step()`` with an optional GIL-releasing dwell — the
serving-layer benchmarks and the process-isolation smoke tests measure
the worker/transport machinery, not model compute, and a spawned child
importing this module pays no jax import.  ``make_stub`` is the
module-level builder the picklable :class:`~repro.core.config.EngineSpec`
points at.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List

import numpy as np

from repro.core.request import StageEvent


class StubEngine:
    """FIFO echo engine: each step finishes one queued item after
    ``dwell_s`` (a sleep, so replicas overlap like independent devices)
    and emits its inputs back as the finished payload."""

    def __init__(self, name: str, dwell_s: float = 0.0):
        self.name = name
        self.dwell_s = dwell_s
        self._q: deque = deque()
        self.busy_time = 0.0
        self.admitted: List[int] = []    # req ids, admission order

    def enqueue(self, req_id: int, inputs: Dict[str, Any], sampling: Any,
                data: Dict[str, Any]) -> None:
        self.admitted.append(req_id)
        self._q.append((req_id, dict(inputs)))

    @property
    def has_work(self) -> bool:
        return bool(self._q)

    @property
    def queue_depth(self) -> int:
        return len(self._q)

    def step(self) -> List[StageEvent]:
        if not self._q:
            return []
        rid, inputs = self._q.popleft()
        if self.dwell_s > 0:
            time.sleep(self.dwell_s)
        self.busy_time += self.dwell_s
        return [StageEvent(rid, "finished", inputs, stage=self.name)]


def make_stub(name: str = "stub", dwell_ms: float = 0.0) -> StubEngine:
    """EngineSpec target: ``repro.engine.stub_engine:make_stub``."""
    return StubEngine(name, dwell_s=dwell_ms / 1e3)


class SeedableStubEngine(StubEngine):
    """Stub exposing the engine-side warm-seed protocol
    (``cached_prefix_pages`` / ``prefix_snapshot`` / ``seed_prefixes`` /
    ``prefix_hint``) with numpy payloads, so the cross-process seed
    transport moves real array bytes.  Each "page" is one small array
    whose contents encode its index — a receiver can verify the seeded
    snapshot byte-for-byte."""

    def __init__(self, name: str, pages: int = 0, dwell_s: float = 0.0):
        super().__init__(name, dwell_s)
        self.seeded_pages = 0
        self._pages: List[Dict[str, Any]] = [self._page(i)
                                             for i in range(pages)]

    @staticmethod
    def _page(i: int) -> Dict[str, Any]:
        return {"hash": i, "k": np.full((4, 8), i, np.float32),
                "v": np.full((4, 8), -i, np.float32)}

    @property
    def cached_prefix_pages(self) -> int:
        return len(self._pages)

    def prefix_snapshot(self, max_pages: int = 64) -> List[Dict[str, Any]]:
        return [dict(p) for p in self._pages[:max_pages]]

    def seed_prefixes(self, snapshot: Any) -> int:
        fresh = [p for p in snapshot
                 if p["hash"] not in {q["hash"] for q in self._pages}]
        self._pages.extend(fresh)
        self.seeded_pages += len(fresh)
        return len(fresh)

    def prefix_hint(self, hints: Any) -> int:
        return len(self._pages)

    def step(self) -> List[StageEvent]:
        # report the page inventory so tests can compare replica state
        # through ordinary finished events
        evs = super().step()
        for ev in evs:
            ev.payload = dict(ev.payload)
            ev.payload["pages"] = sorted(p["hash"] for p in self._pages)
        return evs


def make_seedable(name: str = "stub", pages: int = 0,
                  dwell_ms: float = 0.0) -> SeedableStubEngine:
    """EngineSpec target: ``repro.engine.stub_engine:make_seedable``."""
    return SeedableStubEngine(name, pages=pages, dwell_s=dwell_ms / 1e3)
