"""AR stage execution engine: continuous batching + chunked prefill +
paged-KV decode, with per-iteration preprocess hooks (paper §3.3).

One engine serves one stage. Each ``step()`` executes one scheduler plan:
admissions, prefill chunks, one batched decode, sampling, and event
emission (finished outputs and streamed chunks).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.request import StageEvent
from repro.engine.kv_cache import (PagedKVConfig, embed_prefix_keys,
                                   hash_embed_blocks, hash_token_blocks,
                                   token_prefix_keys)
from repro.engine.runner import PagedRunner, StateRunner
from repro.engine.sampling import SamplingParams, sample_tokens
from repro.engine.scheduler import Scheduler


def _ngram_propose(ctx: List[int], m: int, k: int) -> List[int]:
    """Prompt-lookup drafting: continue the most recent earlier occurrence
    of the trailing m-gram."""
    if len(ctx) < m + 1:
        return []
    key = tuple(ctx[-m:])
    for i in range(len(ctx) - m - 1, -1, -1):
        if tuple(ctx[i:i + m]) == key:
            return [int(t) for t in ctx[i + m:i + m + k]]
    return []


@dataclass
class _ReqRuntime:
    prompt_embeds: Optional[np.ndarray] = None   # (S, d) resolved prompt
    prompt_tokens: Optional[List[int]] = None    # for n-gram drafting
    data: Dict[str, Any] = field(default_factory=dict)
    tokens: List[int] = field(default_factory=list)
    hiddens: List[np.ndarray] = field(default_factory=list)
    last_logits: Optional[jax.Array] = None
    streamed: int = 0
    chunk_index: int = 0
    t_first_sched: Optional[float] = None
    kv_seed: Optional[tuple] = None              # (k, v, prompt_len) — PD


class AREngine:
    def __init__(self, name: str, cfg: ModelConfig, params, *,
                 kv: Optional[PagedKVConfig] = None, max_batch: int = 8,
                 token_budget: int = 256, chunk_size: int = 64,
                 preprocess: Optional[Callable] = None,
                 stream_chunk: int = 0, collect_hidden: bool = False,
                 default_sampling: Optional[SamplingParams] = None,
                 emit_kv: bool = False, enable_prefix_cache: bool = False,
                 prefix_index: str = "radix",
                 spec_ngram: Optional[tuple] = None, seed: int = 0):
        self.name = name
        self.cfg = cfg
        self.kv = kv or PagedKVConfig()
        self.max_batch = max_batch
        self.preprocess = preprocess
        self.stream_chunk = stream_chunk
        self.collect_hidden = collect_hidden
        self.default_sampling = default_sampling
        self.emit_kv = emit_kv   # prefill stage: ship prompt KV on finish
        # n-gram speculative decoding (greedy only): (match_len m, draft_k).
        # Drafts come from prompt-lookup (most recent m-gram match in the
        # context); verification is one chunk forward; rejected drafts'
        # page writes are masked by seq_lens and overwritten later, so
        # rollback is free.
        self.spec_ngram = spec_ngram
        self.spec_stats = {"proposed": 0, "accepted": 0, "steps": 0}
        # prefix caching needs paged KV: SSM state is not content-sharable
        self.enable_prefix_cache = (enable_prefix_cache
                                    and cfg.arch_type not in ("ssm",
                                                              "hybrid"))
        self.scheduler = Scheduler(self.kv, max_batch, token_budget,
                                   chunk_size,
                                   enable_prefix_cache=self.enable_prefix_cache,
                                   prefix_index=prefix_index)
        self._seed_events = 0           # pages warm-seeded into this replica
        if cfg.arch_type in ("ssm", "hybrid"):
            self.runner: Any = StateRunner(cfg, params, self.kv, max_batch)
            self._paged = False
            # SSM prefill is one scan — admit whole prompts as one chunk
            self.scheduler.chunk_size = self.kv.max_seq
        else:
            self.runner = PagedRunner(cfg, params, self.kv)
            self._paged = True
        self._rt: Dict[int, _ReqRuntime] = {}
        self._key = jax.random.PRNGKey(seed)
        self.steps = 0
        self.busy_time = 0.0

    # ------------------------------------------------------------------
    def enqueue(self, req_id: int, inputs: Dict[str, Any],
                sampling: SamplingParams, data: Dict[str, Any]) -> None:
        if self.default_sampling is not None:
            sampling = self.default_sampling
        rt = _ReqRuntime(data=data)
        if "kv_seed" in inputs:
            # PD disaggregation: prompt KV arrives from a prefill stage
            k, v = inputs["kv_seed"]
            n = int(inputs["prompt_len"])
            rt.kv_seed = (np.asarray(k), np.asarray(v), n)
            rt.tokens = [int(inputs["first_token"])]
            if inputs.get("hidden") is not None and self.collect_hidden:
                rt.hiddens = [np.asarray(h) for h in inputs["hidden"]]
            self._rt[req_id] = rt
            self.scheduler.add_prefilled(req_id, n, sampling)
            return
        if "prompt_embeds" in inputs:
            pe = np.asarray(inputs["prompt_embeds"])
        else:
            tokens = np.asarray(inputs["tokens"], np.int32)
            rt.prompt_tokens = [int(t) for t in tokens]
            pe = np.asarray(self.runner.embed(tokens))
        if self.preprocess is not None:
            extra = self.preprocess(data, {"phase": "prefill",
                                           "prompt_len": pe.shape[0]})
            if extra and "prompt_extra" in extra:
                pe = pe + np.asarray(extra["prompt_extra"], pe.dtype)
            if extra and "prompt_prepend" in extra:
                # mm_encode hook (paper Fig 4): multimodal embeddings are
                # concatenated ahead of the text prompt
                pe = np.concatenate(
                    [np.asarray(extra["prompt_prepend"], pe.dtype), pe], 0)
        rt.prompt_embeds = pe
        self._rt[req_id] = rt
        hashes, keys = self._prefix_ids(rt, pe)
        self.scheduler.add(req_id, pe.shape[0], sampling,
                           block_hashes=hashes, prefix_keys=keys)

    def _prefix_ids(self, rt: _ReqRuntime, pe: np.ndarray):
        """Content-addressed (block hashes, per-token sub-keys) over the
        prompt: token ids when the stage is tokenized and per-request
        preprocess cannot perturb the prompt; otherwise bytes digests of
        the final prompt embeds (covers hidden-state-fed stages and mm
        prepends).  Hashes cover full pages (tree edges); sub-keys cover
        every position including the partial tail block, enabling
        partial-block radix hits."""
        if not (self.enable_prefix_cache and self._paged):
            return None, None
        if rt.prompt_tokens is not None and self.preprocess is None:
            return (hash_token_blocks(rt.prompt_tokens, self.kv.page_size),
                    token_prefix_keys(rt.prompt_tokens, self.kv.page_size))
        return (hash_embed_blocks(pe, self.kv.page_size),
                embed_prefix_keys(pe, self.kv.page_size))

    def affinity_hints(self, inputs: Dict[str, Any]):
        """Router-side hint for cache-affinity routing: the (block hashes,
        sub-keys) this request WILL carry if routed here.  Must mirror the
        token path of ``_prefix_ids`` exactly — only tokenized stages
        without per-request preprocess are hintable (embeds are hashed
        post-preprocess, which the router cannot reproduce).  Returns None
        when no stable hint exists."""
        if not (self.enable_prefix_cache and self._paged
                and self.preprocess is None and inputs is not None
                and "kv_seed" not in inputs and "prompt_embeds" not in inputs
                and "tokens" in inputs):
            return None
        return (hash_token_blocks(inputs["tokens"], self.kv.page_size),
                token_prefix_keys(inputs["tokens"], self.kv.page_size))

    def prefix_hint(self, hint) -> int:
        """Matched tokens of ``hint`` (an ``affinity_hints`` result, or a
        bare hash chain) resident in this replica's radix index — full
        blocks score page_size tokens each, plus the partial-block match
        at the divergence.  Read-only, cross-thread safe (the router
        probes every candidate replica with it)."""
        if not (self.enable_prefix_cache and self._paged) or hint is None:
            return 0
        if isinstance(hint, tuple):
            hashes, keys = hint
        else:
            hashes, keys = hint, None
        return self.scheduler.prefix_hint(hashes, keys)

    @property
    def prefix_stats(self) -> Dict[str, int]:
        return dict(self.scheduler.prefix_stats)

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    @property
    def queue_depth(self) -> int:
        """Admitted-but-unfinished plus waiting requests (StageEngine)."""
        return len(self.scheduler.waiting) + len(self.scheduler.running)

    # ------------------------------------------------------------------
    def _sample(self, req_id: int, logits: jax.Array) -> int:
        sp = self.scheduler.running[req_id].sampling
        self._key, sk = jax.random.split(self._key)
        tok = int(sample_tokens(logits[None], sp.temperature, sp.top_k, sk)[0])
        return tok

    def _decode_embed_row(self, req_id: int) -> np.ndarray:
        rt = self._rt[req_id]
        tok = rt.tokens[-1]
        e = np.asarray(self.runner.embed(np.array([tok], np.int32)))[0]
        if self.preprocess is not None:
            extra = self.preprocess(
                rt.data, {"phase": "decode", "step": len(rt.tokens) - 1})
            if extra and "extra_embed" in extra:
                e = e + np.asarray(extra["extra_embed"], e.dtype)
        return e

    def _release(self, req_id: int) -> None:
        """Release a finished request, first extending its block-hash chain
        over generated tokens (token stages without per-request decode
        hooks) so the whole context becomes matchable — a multi-turn
        follow-up that re-sends this conversation hits every page."""
        rt = self._rt.pop(req_id)
        if self.enable_prefix_cache and self._paged \
                and rt.prompt_tokens is not None and self.preprocess is None:
            seq = self.scheduler.running[req_id]
            ctx = rt.prompt_tokens + rt.tokens
            self.scheduler.set_hashes(
                req_id, hash_token_blocks(ctx[:seq.pos], self.kv.page_size),
                token_prefix_keys(ctx[:seq.pos], self.kv.page_size))
        self.scheduler.release(req_id)

    # ---- warm replica scale-up ---------------------------------------
    @property
    def cached_prefix_pages(self) -> int:
        """Published pages in this replica's index (donor-selection
        score for warm scale-up)."""
        if not (self.enable_prefix_cache and self._paged):
            return 0
        return self.scheduler.allocator.indexed_pages

    def prefix_snapshot(self, max_pages: int = 64) -> List[Dict[str, Any]]:
        """Read-only snapshot of this replica's published prefixes for
        seeding a freshly scaled-up sibling: root-to-leaf radix chains
        with their KV contents.  The pages are pinned (extra refcount
        under a negative req-id) while KV is extracted, so the owning
        engine can keep serving concurrently — indexed pages are
        KV-complete and never written by running requests, and the pin
        prevents eviction/reallocation mid-copy."""
        if not (self.enable_prefix_cache and self._paged):
            return []
        alloc = self.scheduler.allocator
        pin, paths = alloc.snapshot_pin(max_pages)
        try:
            out = []
            for hashes, keys, pages in paths:
                bt = np.asarray(pages, np.int32)
                k, v = self.runner.extract_kv(
                    bt, len(pages) * self.kv.page_size)
                out.append({"hashes": hashes, "keys": keys, "k": k, "v": v})
        finally:
            alloc.release_pin(pin)
        return out

    def seed_prefixes(self, snapshot: List[Dict[str, Any]]) -> int:
        """Warm-seed this replica's cache from a sibling's
        ``prefix_snapshot``: allocate pages, inject the transferred KV,
        publish the chain, and release — the pages park in the LRU exactly
        as if a local request had computed them, so affinity routing has
        somewhere to route from the first request on.  Chains sharing a
        prefix with already-seeded ones are deduplicated via lookup.
        Returns the number of pages seeded."""
        if not (self.enable_prefix_cache and self._paged):
            return 0
        alloc = self.scheduler.allocator
        page = self.kv.page_size
        seeded = 0
        for entry in snapshot:
            hashes, keys = entry["hashes"], entry["keys"]
            hit = alloc.lookup(hashes)
            n_new = len(hashes) - len(hit)
            if n_new <= 0:
                continue
            rid = alloc.temp_rid()
            pages = alloc.allocate(rid, n_new)
            if pages is None:
                break                   # pool exhausted: seed what fits
            lo, hi = len(hit) * page, len(hashes) * page
            self.runner.inject_kv(np.asarray(entry["k"])[:, lo:hi],
                                  np.asarray(entry["v"])[:, lo:hi],
                                  np.asarray(pages, np.int32), hi - lo)
            alloc.publish(hit + pages, hashes, keys)
            alloc.free(rid)             # published pages park in the LRU
            seeded += n_new
        self._seed_events += seeded
        return seeded

    def _emit_progress(self, req_id: int, events: List[StageEvent],
                       finished: bool) -> None:
        rt = self._rt[req_id]
        if self.stream_chunk > 0:
            while (len(rt.tokens) - rt.streamed >= self.stream_chunk
                   or (finished and rt.streamed < len(rt.tokens))):
                end = min(rt.streamed + self.stream_chunk, len(rt.tokens))
                payload = {
                    "tokens": np.array(rt.tokens[rt.streamed:end], np.int32),
                    "hidden": (np.stack(rt.hiddens[rt.streamed:end])
                               if self.collect_hidden else None),
                }
                is_last = finished and end == len(rt.tokens)
                events.append(StageEvent(req_id, "chunk", payload,
                                         stage=self.name,
                                         chunk_index=rt.chunk_index,
                                         is_last=is_last))
                rt.chunk_index += 1
                rt.streamed = end
                if end == len(rt.tokens):
                    break
        if finished:
            payload = {
                "tokens": np.array(rt.tokens, np.int32),
                "hidden": (np.stack(rt.hiddens) if self.collect_hidden
                           and rt.hiddens else None),
                "n_chunks": rt.chunk_index,
            }
            if self.emit_kv and self._paged:
                seq = self.scheduler.running[req_id]
                bt = self.scheduler.tables.row(req_id)
                k, v = self.runner.extract_kv(bt, seq.pos)
                payload.update({"kv_k": k, "kv_v": v,
                                "prompt_len": seq.pos})
            events.append(StageEvent(req_id, "finished", payload,
                                     stage=self.name))

    # ------------------------------------------------------------------
    def _spec_decode_one(self, rid: int, events: List[StageEvent]) -> bool:
        """One speculative step for one request. Returns True if handled
        (the request must then be excluded from the batched decode)."""
        seq = self.scheduler.running[rid]
        rt = self._rt[rid]
        if (seq.sampling.temperature > 0 or rt.prompt_tokens is None):
            return False
        m, k = self.spec_ngram
        ctx = rt.prompt_tokens + rt.tokens
        draft = _ngram_propose(ctx, m, k)
        if not draft:
            return False
        # dedicated small verification bucket (one compiled shape)
        bucket = max(8, 1 << (k).bit_length())
        draft = draft[:bucket - 1]
        toks = np.array([rt.tokens[-1]] + draft, np.int32)
        emb = np.asarray(self.runner.embed(toks))
        embp = np.pad(emb, ((0, bucket - emb.shape[0]), (0, 0)))
        bt = self.scheduler.tables.row(rid)
        logits, hidden = self.runner.prefill_chunk(
            jnp.asarray(embp, jnp.dtype(self.cfg.dtype))[None], bt,
            seq.pos, len(toks))
        greedy = np.asarray(jnp.argmax(logits[:len(toks)], axis=-1))
        acc = 0
        while acc < len(draft) and draft[acc] == int(greedy[acc]):
            acc += 1
        emitted = [int(t) for t in greedy[:acc + 1]]
        remaining = seq.sampling.max_new_tokens - seq.generated
        emitted = emitted[:max(1, remaining)]
        self.spec_stats["steps"] += 1
        self.spec_stats["proposed"] += len(draft)
        self.spec_stats["accepted"] += len(emitted) - 1
        for _ in range(len(emitted)):       # KV written: last_tok + accepted
            self.scheduler.note_decode_written(rid)
        finished = False
        for i, tok in enumerate(emitted):
            rt.tokens.append(tok)
            if self.collect_hidden:
                rt.hiddens.append(np.asarray(hidden[i]))
            finished = self.scheduler.note_sampled(rid, tok)
            if finished:
                break
        self._emit_progress(rid, events, finished)
        if finished:
            self._release(rid)
        return True

    def step(self) -> List[StageEvent]:
        t0 = time.perf_counter()
        events: List[StageEvent] = []
        plan = self.scheduler.schedule()
        # preemption (recompute mode): the victim's generated tokens (minus
        # the unwritten last one) join its prompt for re-prefill
        for rid in plan.preempted:
            rt = self._rt.get(rid)
            if rt is None or len(rt.tokens) < 1:
                continue
            # PD-seeded requests have no prompt embeddings to recompute
            # from — never enable preemption on a PD decode stage
            assert rt.prompt_embeds is not None, \
                "preemption is unsupported for KV-seeded (PD) requests"
            gen = np.array(rt.tokens[:-1], np.int32)
            if len(gen):
                rt.prompt_embeds = np.concatenate(
                    [rt.prompt_embeds, np.asarray(self.runner.embed(gen))], 0)
        # prefix cache copy-on-write: a request whose whole page-aligned
        # prompt hit the cache gets a private copy of the final shared page
        # before recomputing (and rewriting) its last token
        if plan.cow_pairs:
            self.runner.copy_pages([s for s, _ in plan.cow_pairs],
                                   [d for _, d in plan.cow_pairs])
        # PD disaggregation: inject transferred KV for newly admitted
        # pre-filled requests before their first decode step
        for rid in plan.admitted:
            rt = self._rt.get(rid)
            if rt is not None and rt.kv_seed is not None:
                k, v, n = rt.kv_seed
                self.runner.inject_kv(
                    k, v, self.scheduler.tables.row(rid), n)
                rt.kv_seed = None
        if not plan.prefill_chunks and not plan.decode_req_ids:
            return events
        self.steps += 1

        # ---- prefill chunks (one request-chunk at a time) --------------
        for ch in plan.prefill_chunks:
            rt = self._rt[ch.req_id]
            seq = self.scheduler.running[ch.req_id]
            emb = rt.prompt_embeds[ch.start:ch.start + ch.length]
            if self._paged:
                # pad to the chunk bucket so jit shapes stay few
                bucket = self.scheduler.chunk_size
                pad = bucket - emb.shape[0] if emb.shape[0] < bucket else 0
                embp = np.pad(emb, ((0, pad), (0, 0)))
                bt = self.scheduler.tables.row(ch.req_id)
                logits, hidden = self.runner.prefill_chunk(
                    jnp.asarray(embp)[None], bt, ch.start, ch.length)
                last_logits = logits[ch.length - 1]
            else:
                logits, _ = self.runner.prefill(
                    jnp.asarray(emb)[None], seq.slot)
                last_logits = logits[-1]
                hidden = None
            self.scheduler.note_prefill(ch.req_id, ch.length)
            if not seq.in_prefill and seq.resumed:
                # resumed after preemption: the next token was already
                # sampled before eviction — decode continues from it
                seq.resumed = False
                continue
            if not seq.in_prefill:
                # prompt complete: sample the first token from prefill logits
                tok = self._sample(ch.req_id, last_logits)
                rt.tokens.append(tok)
                if self.collect_hidden and hidden is not None:
                    rt.hiddens.append(np.asarray(hidden[ch.length - 1]))
                finished = self.scheduler.note_sampled(ch.req_id, tok)
                self._emit_progress(ch.req_id, events, finished)
                if finished:
                    self._release(ch.req_id)

        # ---- batched decode --------------------------------------------
        dec_ids = [r for r in plan.decode_req_ids
                   if r in self.scheduler.running
                   and not self.scheduler.running[r].finished]

        # ---- speculative decode (n-gram draft + chunk verify) -----------
        if self.spec_ngram and self._paged and self.preprocess is None:
            for rid in list(dec_ids):
                if self._spec_decode_one(rid, events):
                    dec_ids.remove(rid)
        if dec_ids:
            B = self.max_batch
            d = self.cfg.d_model
            embeds = np.zeros((B, 1, d), np.float32)
            positions = np.zeros((B,), np.int32)
            active = np.zeros((B,), bool)
            tables = np.zeros((B, self.kv.max_pages_per_seq), np.int32)
            slot_of = {}
            for rid in dec_ids:
                seq = self.scheduler.running[rid]
                s = seq.slot
                slot_of[rid] = s
                embeds[s, 0] = self._decode_embed_row(rid)
                positions[s] = seq.pos
                active[s] = True
                tables[s] = self.scheduler.tables.row(rid)
            dt = jnp.dtype(self.cfg.dtype)
            logits, hidden = self.runner.decode(
                jnp.asarray(embeds, dt), tables, positions, active)
            hidden_np = (np.asarray(hidden) if hidden is not None else None)
            # batch sampling: one jitted call per (temperature, top_k) group
            groups: Dict[tuple, List[int]] = {}
            for rid in dec_ids:
                sp = self.scheduler.running[rid].sampling
                groups.setdefault((sp.temperature, sp.top_k), []).append(rid)
            sampled: Dict[int, int] = {}
            for (temp, tk), rids in groups.items():
                # pad the row-gather to max_batch: one compiled shape
                slots = [slot_of[r] for r in rids]
                rows = jnp.asarray(slots + [0] * (self.max_batch - len(slots)))
                self._key, sk = jax.random.split(self._key)
                toks = np.asarray(sample_tokens(logits[rows], temp, tk, sk))
                sampled.update(zip(rids, toks[:len(rids)].tolist()))
            for rid in dec_ids:
                s = slot_of[rid]
                self.scheduler.note_decode_written(rid)
                tok = int(sampled[rid])
                rt = self._rt[rid]
                rt.tokens.append(tok)
                if self.collect_hidden and hidden_np is not None:
                    rt.hiddens.append(hidden_np[s])
                finished = self.scheduler.note_sampled(rid, tok)
                self._emit_progress(rid, events, finished)
                if finished:
                    self._release(rid)

        self.busy_time += time.perf_counter() - t0
        return events
