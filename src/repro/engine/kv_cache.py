"""Paged KV cache manager (vLLM-style) + SSM state cache.

The page pool is a pair of arrays (L, P, page, nkv, hd); sequences own
pages through int32 block tables. Allocation is a host-side free list; the
device arrays are only touched inside the jitted step functions.

Automatic prefix caching (vLLM-style): the allocator is refcounted and
keeps a content-hash -> page index over *full* pages.  A page is always in
exactly one of three states:

  - **free**: on the free list, content meaningless;
  - **cached**: refcount 0 but content-indexed; parked in an LRU from
    which it can be re-acquired by hash (prefix hit) or evicted;
  - **referenced**: refcount >= 1, held by one or more requests (the same
    physical page backs every request whose prompt shares the prefix).

Block hashes form a chain — hash_i = H(hash_{i-1}, page_i contents) — so a
hit on block i implies the whole prefix up to i matches.  Contents are
token ids for tokenized stages and a bytes digest of the prompt *embeds*
for stages fed hidden states (Thinker -> Talker), so every AR stage of an
any-to-any pipeline can prefix-cache.

The index itself is a radix tree over the hash chain
(``engine/radix_index.py``): longest-prefix walks, *partial-block* hits
via per-token sub-keys, leaf-ordered LRU eviction, and snapshot paths a
sibling replica can warm-seed a scale-up from.  ``index_kind="flat"``
keeps the PR-6 flat map as the ablation baseline.

SSM stages have no KV: their cache is a constant-size recurrent state per
slot, managed by ``SlotStateCache`` (DESIGN.md §4 — per-stage cache kind).
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.radix_index import (BlockKey, PartialHit,  # noqa: F401
                                      make_index)

BlockHash = Tuple[str, bytes]


def _digest(parent: bytes, payload: bytes) -> bytes:
    return hashlib.blake2b(parent + payload, digest_size=16).digest()


def hash_token_blocks(tokens, page_size: int,
                      parent: bytes = b"") -> List[BlockHash]:
    """Chained content hashes over the FULL pages of a token sequence."""
    arr = np.asarray(tokens, np.int64)
    out: List[BlockHash] = []
    h = parent
    for i in range(len(arr) // page_size):
        h = _digest(h, arr[i * page_size:(i + 1) * page_size].tobytes())
        out.append(("tok", h))
    return out


def hash_embed_blocks(embeds, page_size: int,
                      parent: bytes = b"") -> List[BlockHash]:
    """Chained bytes-digests over the FULL pages of a prompt-embeds matrix
    (stages whose prompts are hidden states rather than token ids)."""
    e = np.ascontiguousarray(np.asarray(embeds, np.float32))
    out: List[BlockHash] = []
    h = parent
    for i in range(e.shape[0] // page_size):
        h = _digest(h, e[i * page_size:(i + 1) * page_size].tobytes())
        out.append(("emb", h))
    return out


def token_prefix_keys(tokens, page_size: int) -> List[BlockKey]:
    """Per-token sub-keys, one tuple per block *including* the partial
    tail block: the radix index compares these at the diverging block to
    find partial-page hits.  For token stages the sub-key of a position is
    the token id itself — equal sub-keys literally mean equal tokens, so a
    partial match's copied KV rows are exactly what a fresh prefill would
    write."""
    arr = np.asarray(tokens, np.int64)
    return [tuple(int(t) for t in arr[i:i + page_size])
            for i in range(0, len(arr), page_size)]


def embed_prefix_keys(embeds, page_size: int) -> List[BlockKey]:
    """Per-row digests for embed-fed stages: two rows with equal digests
    have byte-identical embeddings, so prefix-matching digests is as sound
    as matching token ids."""
    e = np.ascontiguousarray(np.asarray(embeds, np.float32))
    digests = [hashlib.blake2b(e[i].tobytes(), digest_size=8).digest()
               for i in range(e.shape[0])]
    return [tuple(digests[i:i + page_size])
            for i in range(0, len(digests), page_size)]


class PageAllocator:
    """Refcounted page allocator with an optional content-addressed
    prefix cache (``enable_prefix_cache``).  With the cache disabled the
    behavior is exactly the old free-list allocator (no page is ever
    indexed, so every released page returns straight to the free list).

    The index is a ``RadixIndex`` by default (``index_kind="flat"`` keeps
    the PR-6 map as the ablation baseline).  Mutators take ``_lock`` so a
    sibling replica can pin a consistent snapshot cross-thread
    (``snapshot_pin``/``release_pin``) while the owning engine keeps
    serving; the read-only ``prefix_hint`` router probe stays lock-free.
    """

    def __init__(self, num_pages: int, enable_prefix_cache: bool = False,
                 index_kind: str = "radix", page_size: int = 16):
        self.num_pages = num_pages
        self.enable_prefix_cache = enable_prefix_cache
        self.page_size = page_size
        self.index_kind = index_kind
        self._index = make_index(index_kind)
        # guarded-by-writes: _lock (mutation locked; advisory lock-free
        # reads are the documented contract of the stats properties)
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        # pages held per request, WITH multiplicity: the total multiplicity
        # of a page across requests equals its refcount
        self._owned: Dict[int, List[int]] = {}   # guarded-by-writes: _lock
        self._refcount: Dict[int, int] = {}      # guarded-by-writes: _lock
        # cached pages with refcount 0, oldest first (eviction order);
        # eviction takes the first *leaf* in this order
        self._lru: "OrderedDict[int, None]" = (
            OrderedDict())                       # guarded-by-writes: _lock
        self.evictions = 0                       # guarded-by-writes: _lock
        self._lock = threading.RLock()
        self._pin_rid = -1              # negative req-ids for snapshot pins

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages retained only for their cached content."""
        return len(self._lru)

    @property
    def reusable_pages(self) -> int:
        return len(self._free) + len(self._lru)

    def refcount(self, page: int) -> int:
        return self._refcount.get(page, 0)

    def pages_owned(self, req_id: int) -> List[int]:
        return self._owned.get(req_id, [])

    @property
    def indexed_pages(self) -> int:
        return len(self._index)

    # -- allocation ---------------------------------------------------------
    def _evict_one(self) -> bool:  # requires-lock: _lock
        """Evict the coldest *evictable* cached page: oldest-first in LRU
        order, skipping interior radix nodes with live descendants.  A
        skipped interior page becomes evictable once its subtree is gone
        (children are always parked no earlier than their parents only if
        acquired together; regardless, removing leaves peels the tree
        bottom-up so repeated calls make progress)."""
        page = self._index.pick_evictable(self._lru)
        if page is None:
            return False
        del self._lru[page]
        self._index.remove(page)
        self._free.append(page)
        self.evictions += 1
        return True

    def allocate(self, req_id: int, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh (private, refcount-1) pages, evicting
        cached pages as needed.  Referenced pages are never evicted."""
        with self._lock:
            if len(self._free) + len(self._lru) < n:
                return None
            while len(self._free) < n:
                if not self._evict_one():
                    return None       # no evictable leaf (treat as OOM)
            pages = [self._free.pop() for _ in range(n)]
            for p in pages:
                self._refcount[p] = 1
            self._owned.setdefault(req_id, []).extend(pages)
            return pages

    # -- prefix cache -------------------------------------------------------
    def lookup(self, hashes: Sequence[BlockHash]) -> List[int]:
        """Longest cached full-block prefix (no refcounts taken).  An
        O(match length) walk down the radix tree — the scan stops at the
        first miss and never touches the rest of the index."""
        return self._index.lookup(hashes)

    def match(self, hashes: Sequence[BlockHash],
              keys: Optional[Sequence[Optional[BlockKey]]] = None,
              ) -> Tuple[List[int], Optional[PartialHit]]:
        """Longest cached full-block prefix plus the best partial-block
        hit ``(page, matched_tokens)`` at the diverging block (None for
        the flat index)."""
        return self._index.match(hashes, keys)

    def prefix_hint(self, hashes: Sequence[BlockHash],
                    keys: Optional[Sequence[Optional[BlockKey]]] = None,
                    ) -> int:
        """Matched-token count (full blocks * page_size + partial-block
        tokens) of the longest indexed prefix of ``hashes``.  The cheap
        read-only probe behind cache-affinity routing: the router calls it
        cross-thread on every candidate replica, so it must not touch
        refcounts, the LRU, or any allocator state."""
        return self._index.hint(hashes, keys, self.page_size)

    def acquire(self, req_id: int, pages: Iterable[int]) -> None:
        """Take a reference on already-resident pages (a prefix hit, or an
        extra share).  Refcount-0 cached pages leave the eviction LRU."""
        with self._lock:
            owned = self._owned.setdefault(req_id, [])
            for p in pages:
                rc = self._refcount.get(p, 0)
                if rc == 0:
                    self._lru.pop(p)          # must be a cached page
                self._refcount[p] = rc + 1
                owned.append(p)

    def publish(self, pages: Sequence[int], hashes: Sequence[BlockHash],
                keys: Optional[Sequence[Optional[BlockKey]]] = None,
                ) -> None:
        """Insert the chain of full, KV-complete pages into the index so
        future requests can reuse them.  Chains are root-anchored (the
        caller passes the *whole* prefix from block 0, not a suffix).
        First writer wins per block: an existing node keeps its page (the
        duplicate page stays unindexed and returns to the free list on
        release).  ``keys`` carries per-token sub-keys enabling partial
        hits against these blocks."""
        if not self.enable_prefix_cache:
            return
        with self._lock:
            self._index.insert(hashes, pages, keys)

    def cow(self, req_id: int, page: int) -> Optional[int]:
        """Copy-on-write: give ``req_id`` a private writable page standing
        in for shared/cached ``page`` (which it must already hold).  The
        reference on the source is retained until ``free(req_id)`` so it
        cannot be evicted before the caller copies its contents.  Returns
        the private page, or None if the pool is exhausted."""
        assert page in self._owned.get(req_id, ()), "CoW of an unheld page"
        got = self.allocate(req_id, 1)
        return got[0] if got else None

    # -- snapshot (warm replica scale-up) -----------------------------------
    def temp_rid(self) -> int:
        """A fresh negative req-id for internal holds (snapshot pins,
        warm-seed injections) — real requests are non-negative, so these
        can never collide."""
        with self._lock:
            rid = self._pin_rid
            self._pin_rid -= 1
            return rid

    def snapshot_pin(self, max_pages: int = 0):
        """Pin a consistent read-only snapshot of the published prefixes:
        returns ``(pin_id, paths)`` where paths are root-to-leaf
        ``(hashes, keys, pages)`` chains and every covered page holds an
        extra reference under ``pin_id`` (a negative req-id, so it can
        never collide with real requests).  The caller extracts KV from
        the pinned pages *outside* the lock — pinned pages cannot be
        evicted or reallocated, and indexed pages are KV-complete so no
        running request writes into them — then calls ``release_pin``."""
        with self._lock:
            paths = self._index.paths(max_pages)
            pin = self.temp_rid()
            seen = set()
            pages = [p for _, _, pp in paths for p in pp
                     if not (p in seen or seen.add(p))]
            self.acquire(pin, pages)
            return pin, paths

    def release_pin(self, pin_id: int) -> None:
        self.free(pin_id)

    # -- release ------------------------------------------------------------
    def _decref(self, page: int) -> None:  # requires-lock: _lock
        rc = self._refcount[page] - 1
        if rc > 0:
            self._refcount[page] = rc
            return
        del self._refcount[page]
        if self._index.has_page(page):
            self._lru[page] = None            # park: reusable via its hash
            self._lru.move_to_end(page)
        else:
            self._free.append(page)

    def free(self, req_id: int) -> None:
        """Drop every reference ``req_id`` holds.  Shared pages survive for
        their other holders; cached pages park in the LRU."""
        with self._lock:
            for p in self._owned.pop(req_id, []):
                self._decref(p)

    def check_invariant(self) -> bool:
        with self._lock:
            ref_pages = set(self._refcount)
            free_set = set(self._free)
            lru_set = set(self._lru)
            idx_pages = set(self._index.pages())
            # free / cached / referenced partition the pool
            ok = (len(self._free) == len(free_set)
                  and not (free_set & lru_set)
                  and not (free_set & ref_pages)
                  and not (lru_set & ref_pages)
                  and len(free_set) + len(lru_set) + len(ref_pages)
                  == self.num_pages)
            # refcount conservation: refcount == ownership multiplicity >= 1
            mult: Dict[int, int] = {}
            for pages in self._owned.values():
                for p in pages:
                    mult[p] = mult.get(p, 0) + 1
            ok = ok and mult == self._refcount
            # index structure: hash/page bijection, parent/child link
            # consistency, every node reachable from the root (radix:
            # prefix closure — an indexed block implies its whole chain)
            ok = ok and self._index.check()
            # tree shape and page states agree: every indexed page is
            # resident — parked in the LRU (cached) or held by a request
            # (referenced); never on the free list.  A page the index
            # points at but neither state owns would be silently
            # resurrectable garbage
            ok = ok and not (idx_pages & free_set)
            ok = ok and idx_pages <= (lru_set | ref_pages)
            # every refcount-0 cached page is re-acquirable by hash
            ok = ok and lru_set <= idx_pages
            return ok


@dataclass
class PagedKVConfig:
    num_pages: int = 128
    page_size: int = 16
    max_pages_per_seq: int = 16

    @property
    def max_seq(self) -> int:
        return self.page_size * self.max_pages_per_seq


def init_kv_pages(cfg: ModelConfig, kv: PagedKVConfig, num_layers: int):
    dtype = (jnp.int8 if cfg.kv_cache_dtype == "int8"
             else jnp.dtype(cfg.dtype))
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def init_kv_scale_pages(cfg: ModelConfig, kv: PagedKVConfig,
                        num_layers: int):
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class BlockTableStore:
    """Host-side block tables, padded to max_pages_per_seq with 0."""

    def __init__(self, kv: PagedKVConfig):
        self.kv = kv
        self.tables: Dict[int, List[int]] = {}

    def set(self, req_id: int, pages: List[int]) -> None:
        assert len(pages) <= self.kv.max_pages_per_seq, \
            f"request needs {len(pages)} pages > max_pages_per_seq"
        self.tables[req_id] = list(pages)

    def extend(self, req_id: int, pages: List[int]) -> None:
        self.tables.setdefault(req_id, []).extend(pages)

    def row(self, req_id: int) -> np.ndarray:
        t = self.tables.get(req_id, [])
        row = np.zeros(self.kv.max_pages_per_seq, np.int32)
        row[:len(t)] = t
        return row

    def drop(self, req_id: int) -> None:
        self.tables.pop(req_id, None)
