"""Paged KV cache manager (vLLM-style) + SSM state cache.

The page pool is a pair of arrays (L, P, page, nkv, hd); sequences own
pages through int32 block tables. Allocation is a host-side free list; the
device arrays are only touched inside the jitted step functions.

SSM stages have no KV: their cache is a constant-size recurrent state per
slot, managed by ``SlotStateCache`` (DESIGN.md §4 — per-stage cache kind).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class PageAllocator:
    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._owned: Dict[int, List[int]] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_owned(self, req_id: int) -> List[int]:
        return self._owned.get(req_id, [])

    def allocate(self, req_id: int, n: int) -> Optional[List[int]]:
        if len(self._free) < n:
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(req_id, []).extend(pages)
        return pages

    def free(self, req_id: int) -> None:
        pages = self._owned.pop(req_id, [])
        self._free.extend(pages)

    def check_invariant(self) -> bool:
        owned = sum(len(v) for v in self._owned.values())
        in_free = len(self._free)
        no_dupes = len(set(self._free)) == in_free
        disjoint = not (set(self._free)
                        & {p for v in self._owned.values() for p in v})
        return owned + in_free == self.num_pages and no_dupes and disjoint


@dataclass
class PagedKVConfig:
    num_pages: int = 128
    page_size: int = 16
    max_pages_per_seq: int = 16

    @property
    def max_seq(self) -> int:
        return self.page_size * self.max_pages_per_seq


def init_kv_pages(cfg: ModelConfig, kv: PagedKVConfig, num_layers: int):
    dtype = (jnp.int8 if cfg.kv_cache_dtype == "int8"
             else jnp.dtype(cfg.dtype))
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def init_kv_scale_pages(cfg: ModelConfig, kv: PagedKVConfig,
                        num_layers: int):
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class BlockTableStore:
    """Host-side block tables, padded to max_pages_per_seq with 0."""

    def __init__(self, kv: PagedKVConfig):
        self.kv = kv
        self.tables: Dict[int, List[int]] = {}

    def set(self, req_id: int, pages: List[int]) -> None:
        assert len(pages) <= self.kv.max_pages_per_seq, \
            f"request needs {len(pages)} pages > max_pages_per_seq"
        self.tables[req_id] = list(pages)

    def extend(self, req_id: int, pages: List[int]) -> None:
        self.tables.setdefault(req_id, []).extend(pages)

    def row(self, req_id: int) -> np.ndarray:
        t = self.tables.get(req_id, [])
        row = np.zeros(self.kv.max_pages_per_seq, np.int32)
        row[:len(t)] = t
        return row

    def drop(self, req_id: int) -> None:
        self.tables.pop(req_id, None)
