"""Paged KV cache manager (vLLM-style) + SSM state cache.

The page pool is a pair of arrays (L, P, page, nkv, hd); sequences own
pages through int32 block tables. Allocation is a host-side free list; the
device arrays are only touched inside the jitted step functions.

Automatic prefix caching (vLLM-style): the allocator is refcounted and
keeps a content-hash -> page index over *full* pages.  A page is always in
exactly one of three states:

  - **free**: on the free list, content meaningless;
  - **cached**: refcount 0 but content-indexed; parked in an LRU from
    which it can be re-acquired by hash (prefix hit) or evicted;
  - **referenced**: refcount >= 1, held by one or more requests (the same
    physical page backs every request whose prompt shares the prefix).

Block hashes form a chain — hash_i = H(hash_{i-1}, page_i contents) — so a
hit on block i implies the whole prefix up to i matches.  Contents are
token ids for tokenized stages and a bytes digest of the prompt *embeds*
for stages fed hidden states (Thinker -> Talker), so every AR stage of an
any-to-any pipeline can prefix-cache.

SSM stages have no KV: their cache is a constant-size recurrent state per
slot, managed by ``SlotStateCache`` (DESIGN.md §4 — per-stage cache kind).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

BlockHash = Tuple[str, bytes]


def _digest(parent: bytes, payload: bytes) -> bytes:
    return hashlib.blake2b(parent + payload, digest_size=16).digest()


def hash_token_blocks(tokens, page_size: int,
                      parent: bytes = b"") -> List[BlockHash]:
    """Chained content hashes over the FULL pages of a token sequence."""
    arr = np.asarray(tokens, np.int64)
    out: List[BlockHash] = []
    h = parent
    for i in range(len(arr) // page_size):
        h = _digest(h, arr[i * page_size:(i + 1) * page_size].tobytes())
        out.append(("tok", h))
    return out


def hash_embed_blocks(embeds, page_size: int,
                      parent: bytes = b"") -> List[BlockHash]:
    """Chained bytes-digests over the FULL pages of a prompt-embeds matrix
    (stages whose prompts are hidden states rather than token ids)."""
    e = np.ascontiguousarray(np.asarray(embeds, np.float32))
    out: List[BlockHash] = []
    h = parent
    for i in range(e.shape[0] // page_size):
        h = _digest(h, e[i * page_size:(i + 1) * page_size].tobytes())
        out.append(("emb", h))
    return out


class PageAllocator:
    """Refcounted page allocator with an optional content-addressed
    prefix cache (``enable_prefix_cache``).  With the cache disabled the
    behavior is exactly the old free-list allocator (no page is ever
    hashed, so every released page returns straight to the free list)."""

    def __init__(self, num_pages: int, enable_prefix_cache: bool = False):
        self.num_pages = num_pages
        self.enable_prefix_cache = enable_prefix_cache
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        # pages held per request, WITH multiplicity: the total multiplicity
        # of a page across requests equals its refcount
        self._owned: Dict[int, List[int]] = {}
        self._refcount: Dict[int, int] = {}
        self._hash_to_page: Dict[BlockHash, int] = {}
        self._page_hash: Dict[int, BlockHash] = {}
        # cached pages with refcount 0, oldest first (eviction order)
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.evictions = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages retained only for their cached content."""
        return len(self._lru)

    @property
    def reusable_pages(self) -> int:
        return len(self._free) + len(self._lru)

    def refcount(self, page: int) -> int:
        return self._refcount.get(page, 0)

    def pages_owned(self, req_id: int) -> List[int]:
        return self._owned.get(req_id, [])

    # -- allocation ---------------------------------------------------------
    def _evict_one(self) -> None:
        page, _ = self._lru.popitem(last=False)       # oldest cached page
        h = self._page_hash.pop(page)
        del self._hash_to_page[h]
        self._free.append(page)
        self.evictions += 1

    def allocate(self, req_id: int, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh (private, refcount-1) pages, evicting LRU
        cached pages as needed.  Referenced pages are never evicted."""
        if len(self._free) + len(self._lru) < n:
            return None
        while len(self._free) < n:
            self._evict_one()
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refcount[p] = 1
        self._owned.setdefault(req_id, []).extend(pages)
        return pages

    # -- prefix cache -------------------------------------------------------
    def lookup(self, hashes: Iterable[BlockHash]) -> List[int]:
        """Longest cached prefix: pages for the leading run of hashes that
        are present in the index (no refcounts are taken).  One O(1) dict
        probe per block — hashes chain, so the scan stops at the first
        miss and never walks the whole index."""
        pages: List[int] = []
        for h in hashes:
            p = self._hash_to_page.get(h)
            if p is None:
                break
            pages.append(p)
        return pages

    def prefix_hint(self, hashes: Iterable[BlockHash]) -> int:
        """Length (in blocks) of the longest indexed prefix of ``hashes``.
        The cheap read-only probe behind cache-affinity routing: the
        router calls it cross-thread on every candidate replica, so it
        must not touch refcounts, the LRU, or any allocator state."""
        n = 0
        for h in hashes:
            if h not in self._hash_to_page:
                break
            n += 1
        return n

    def acquire(self, req_id: int, pages: Iterable[int]) -> None:
        """Take a reference on already-resident pages (a prefix hit, or an
        extra share).  Refcount-0 cached pages leave the eviction LRU."""
        owned = self._owned.setdefault(req_id, [])
        for p in pages:
            rc = self._refcount.get(p, 0)
            if rc == 0:
                self._lru.pop(p)              # must be a cached page
            self._refcount[p] = rc + 1
            owned.append(p)

    def publish(self, pages: Iterable[int],
                hashes: Iterable[BlockHash]) -> None:
        """Register content hashes for full, KV-complete pages so future
        requests can reuse them.  First writer wins: a hash already in the
        index keeps its existing page (the duplicate page stays unhashed
        and returns to the free list on release)."""
        if not self.enable_prefix_cache:
            return
        for p, h in zip(pages, hashes):
            if h in self._hash_to_page or p in self._page_hash:
                continue
            self._hash_to_page[h] = p
            self._page_hash[p] = h

    def cow(self, req_id: int, page: int) -> Optional[int]:
        """Copy-on-write: give ``req_id`` a private writable page standing
        in for shared/cached ``page`` (which it must already hold).  The
        reference on the source is retained until ``free(req_id)`` so it
        cannot be evicted before the caller copies its contents.  Returns
        the private page, or None if the pool is exhausted."""
        assert page in self._owned.get(req_id, ()), "CoW of an unheld page"
        got = self.allocate(req_id, 1)
        return got[0] if got else None

    # -- release ------------------------------------------------------------
    def _decref(self, page: int) -> None:
        rc = self._refcount[page] - 1
        if rc > 0:
            self._refcount[page] = rc
            return
        del self._refcount[page]
        if page in self._page_hash:
            self._lru[page] = None            # park: reusable via its hash
            self._lru.move_to_end(page)
        else:
            self._free.append(page)

    def free(self, req_id: int) -> None:
        """Drop every reference ``req_id`` holds.  Shared pages survive for
        their other holders; cached pages park in the LRU."""
        for p in self._owned.pop(req_id, []):
            self._decref(p)

    def check_invariant(self) -> bool:
        ref_pages = set(self._refcount)
        free_set = set(self._free)
        lru_set = set(self._lru)
        # free / cached / referenced partition the pool
        ok = (len(self._free) == len(free_set)
              and not (free_set & lru_set)
              and not (free_set & ref_pages)
              and not (lru_set & ref_pages)
              and len(free_set) + len(lru_set) + len(ref_pages)
              == self.num_pages)
        # refcount conservation: refcount == ownership multiplicity >= 1
        mult: Dict[int, int] = {}
        for pages in self._owned.values():
            for p in pages:
                mult[p] = mult.get(p, 0) + 1
        ok = ok and mult == self._refcount
        # hash index is a bijection; hashed pages are never on the free list
        ok = ok and len(self._hash_to_page) == len(self._page_hash)
        ok = ok and all(self._hash_to_page.get(h) == p
                        for p, h in self._page_hash.items())
        ok = ok and not (set(self._page_hash) & free_set)
        # index and page states agree: every indexed page is resident —
        # either parked in the LRU (cached) or held by a request
        # (referenced); a page the index points at but neither state owns
        # would be silently resurrectable garbage
        ok = ok and set(self._page_hash) <= (lru_set | ref_pages)
        # every refcount-0 cached page is re-acquirable by hash
        ok = ok and lru_set <= set(self._page_hash)
        return ok


@dataclass
class PagedKVConfig:
    num_pages: int = 128
    page_size: int = 16
    max_pages_per_seq: int = 16

    @property
    def max_seq(self) -> int:
        return self.page_size * self.max_pages_per_seq


def init_kv_pages(cfg: ModelConfig, kv: PagedKVConfig, num_layers: int):
    dtype = (jnp.int8 if cfg.kv_cache_dtype == "int8"
             else jnp.dtype(cfg.dtype))
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads,
             cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def init_kv_scale_pages(cfg: ModelConfig, kv: PagedKVConfig,
                        num_layers: int):
    shape = (num_layers, kv.num_pages, kv.page_size, cfg.num_kv_heads)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def pages_for(tokens: int, page_size: int) -> int:
    return -(-tokens // page_size)


class BlockTableStore:
    """Host-side block tables, padded to max_pages_per_seq with 0."""

    def __init__(self, kv: PagedKVConfig):
        self.kv = kv
        self.tables: Dict[int, List[int]] = {}

    def set(self, req_id: int, pages: List[int]) -> None:
        assert len(pages) <= self.kv.max_pages_per_seq, \
            f"request needs {len(pages)} pages > max_pages_per_seq"
        self.tables[req_id] = list(pages)

    def extend(self, req_id: int, pages: List[int]) -> None:
        self.tables.setdefault(req_id, []).extend(pages)

    def row(self, req_id: int) -> np.ndarray:
        t = self.tables.get(req_id, [])
        row = np.zeros(self.kv.max_pages_per_seq, np.int32)
        row[:len(t)] = t
        return row

    def drop(self, req_id: int) -> None:
        self.tables.pop(req_id, None)
