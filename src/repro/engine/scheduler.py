"""Per-stage scheduler: continuous batching with chunked prefill.

Sarathi-style: every engine step has a token budget shared between decode
tokens (one per running decode sequence) and prefill chunks; new requests
are admitted whenever a batch slot and enough KV pages are available.
Invariants (property-tested in tests/test_scheduler.py):
  - a slot is owned by at most one request;
  - page accounting conserves the pool;
  - FIFO admission (no starvation): waiting requests admit in arrival order;
  - per-step scheduled tokens <= token_budget (unless a single decode set
    already exceeds it — decodes are never dropped).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.engine.kv_cache import (BlockTableStore, PageAllocator,
                                   PagedKVConfig, pages_for)
from repro.engine.sampling import SamplingParams


@dataclass
class SeqState:
    req_id: int
    prompt_len: int
    sampling: SamplingParams
    slot: int = -1
    prefill_done: int = 0              # prompt tokens already processed
    generated: int = 0
    pos: int = 0                       # next position to write
    finished: bool = False
    resumed: bool = False              # re-prefilling after preemption

    @property
    def in_prefill(self) -> bool:
        return self.prefill_done < self.prompt_len


@dataclass
class ScheduledChunk:
    req_id: int
    start: int                         # first prompt position in this chunk
    length: int                        # real tokens in the chunk


@dataclass
class StepPlan:
    prefill_chunks: List[ScheduledChunk] = field(default_factory=list)
    decode_req_ids: List[int] = field(default_factory=list)
    admitted: List[int] = field(default_factory=list)
    preempted: List[int] = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return (sum(c.length for c in self.prefill_chunks)
                + len(self.decode_req_ids))


class Scheduler:
    def __init__(self, kv: PagedKVConfig, max_batch: int,
                 token_budget: int = 256, chunk_size: int = 64,
                 enable_preemption: bool = False):
        self.kv = kv
        self.max_batch = max_batch
        self.token_budget = token_budget
        self.chunk_size = chunk_size
        self.enable_preemption = enable_preemption
        self.allocator = PageAllocator(kv.num_pages)
        self.tables = BlockTableStore(kv)
        self.waiting: Deque[SeqState] = deque()
        self.running: Dict[int, SeqState] = {}
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self.preemptions = 0

    # ------------------------------------------------------------------
    def add(self, req_id: int, prompt_len: int,
            sampling: SamplingParams) -> None:
        self.waiting.append(SeqState(req_id, prompt_len, sampling))

    def add_prefilled(self, req_id: int, prompt_len: int,
                      sampling: SamplingParams) -> None:
        """Admit a request whose prompt KV was computed by a remote prefill
        stage (PD disaggregation): no prefill chunks are scheduled; the
        engine injects the transferred KV on admission."""
        self.waiting.append(SeqState(req_id, prompt_len, sampling,
                                     prefill_done=prompt_len,
                                     generated=1, pos=prompt_len))

    def _admission_pages(self, seq: SeqState) -> int:
        """Pages reserved at admission. With preemption the pool grows
        incrementally during decode (vLLM-style); without it, the full
        prompt+max_new worth is reserved upfront so admission can't
        deadlock mid-decode."""
        if self.enable_preemption:
            tokens = seq.prompt_len
        else:
            tokens = seq.prompt_len + seq.sampling.max_new_tokens
        return min(pages_for(tokens, self.kv.page_size),
                   self.kv.max_pages_per_seq)

    def _try_admit(self, plan: StepPlan) -> None:
        while self.waiting and self._free_slots:
            seq = self.waiting[0]
            pages = self.allocator.allocate(seq.req_id,
                                            self._admission_pages(seq))
            if pages is None:
                break                   # FIFO: don't skip ahead of the head
            seq.slot = self._free_slots.pop()
            self.tables.set(seq.req_id, pages)
            self.running[seq.req_id] = seq
            plan.admitted.append(seq.req_id)
            self.waiting.popleft()

    def _preempt(self, victim: SeqState, plan: StepPlan) -> None:
        """Recompute-mode preemption: free the victim's pages + slot and
        push it to the front of the waiting queue for re-prefill."""
        rid = victim.req_id
        self.running.pop(rid)
        self.allocator.free(rid)
        self.tables.drop(rid)
        self._free_slots.append(victim.slot)
        plan.preempted.append(rid)
        # reset for recompute: generated tokens (minus the last sampled one,
        # whose KV was never written) join the prompt; the engine extends
        # the prompt embeddings and skips the prefill-completion sample
        victim.slot = -1
        victim.prefill_done = 0
        victim.pos = 0
        if victim.generated >= 1:
            victim.prompt_len += victim.generated - 1
            victim.resumed = True
        self.waiting.appendleft(victim)
        self.preemptions += 1

    def _ensure_decode_capacity(self, plan: StepPlan) -> None:
        """Incremental page growth for running decodes; on OOM, preempt the
        youngest running request so the oldest always makes progress
        (age-ordered eviction can't thrash)."""
        for seq in sorted(self.running.values(), key=lambda s: s.req_id):
            if seq.req_id not in self.running or seq.finished \
                    or seq.in_prefill:
                continue
            while (pages_for(seq.pos + 1, self.kv.page_size)
                   > len(self.allocator.pages_owned(seq.req_id))):
                got = self.allocator.allocate(seq.req_id, 1)
                if got is not None:
                    self.tables.extend(seq.req_id, got)
                    continue
                victims = [s for s in self.running.values()
                           if not s.finished and s.req_id > seq.req_id]
                if victims:
                    self._preempt(max(victims, key=lambda s: s.req_id), plan)
                else:
                    self._preempt(seq, plan)     # evict itself; retry later
                    break

    def schedule(self) -> StepPlan:
        """Plan one engine step."""
        plan = StepPlan()
        self._try_admit(plan)
        if self.enable_preemption:
            self._ensure_decode_capacity(plan)
        budget = self.token_budget
        # decodes first (latency-critical; never dropped)
        for seq in self.running.values():
            if not seq.in_prefill and not seq.finished:
                plan.decode_req_ids.append(seq.req_id)
        budget -= len(plan.decode_req_ids)
        # prefill chunks with the remaining budget
        for seq in self.running.values():
            if budget <= 0:
                break
            if seq.in_prefill:
                n = min(self.chunk_size, seq.prompt_len - seq.prefill_done,
                        max(budget, 0))
                if n > 0:
                    plan.prefill_chunks.append(
                        ScheduledChunk(seq.req_id, seq.prefill_done, n))
                    budget -= n
        return plan

    # ------------------------------------------------------------------
    def note_prefill(self, req_id: int, n: int) -> None:
        seq = self.running[req_id]
        seq.prefill_done += n
        seq.pos = seq.prefill_done      # pos = #tokens whose KV is written

    def note_decode_written(self, req_id: int) -> None:
        """One decode step wrote this request's current token KV at seq.pos."""
        self.running[req_id].pos += 1

    def note_sampled(self, req_id: int, token: int) -> bool:
        """Record one sampled token; returns True if the request finished."""
        seq = self.running[req_id]
        seq.generated += 1
        sp = seq.sampling
        if (seq.generated >= sp.max_new_tokens
                or (sp.eos_token >= 0 and token == sp.eos_token)
                or seq.pos + 1 >= self.kv.max_seq):
            seq.finished = True
        return seq.finished

    def release(self, req_id: int) -> None:
        seq = self.running.pop(req_id)
        self.allocator.free(req_id)
        self.tables.drop(req_id)
        self._free_slots.append(seq.slot)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)
