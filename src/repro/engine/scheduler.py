"""Per-stage scheduler: continuous batching with chunked prefill.

Sarathi-style: every engine step has a token budget shared between decode
tokens (one per running decode sequence) and prefill chunks; new requests
are admitted whenever a batch slot and enough KV pages are available.
Invariants (property-tested in tests/test_scheduler.py and
tests/test_kv_prefix_cache.py):
  - a slot is owned by at most one request;
  - page accounting conserves the pool (refcount-aware with prefix cache);
  - FIFO admission (no starvation): waiting requests admit in arrival order
    and a cache hit never lets a later request jump the queue;
  - per-step scheduled tokens <= token_budget (unless a single decode set
    already exceeds it — decodes are never dropped);
  - a request never writes KV into a page another request can read: shared
    cached pages sit strictly before a sequence's write position, and a
    fully-cached final prompt page is replaced by a copy-on-write copy.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.engine.kv_cache import (BlockHash, BlockKey, BlockTableStore,
                                   PageAllocator, PagedKVConfig, pages_for)
from repro.engine.sampling import SamplingParams


@dataclass
class SeqState:
    req_id: int
    prompt_len: int
    sampling: SamplingParams
    slot: int = -1
    prefill_done: int = 0              # prompt tokens already processed
    generated: int = 0
    pos: int = 0                       # next position to write
    finished: bool = False
    resumed: bool = False              # re-prefilling after preemption
    block_hashes: List[BlockHash] = field(default_factory=list)
    # per-token sub-keys per block (incl. the partial tail block) — the
    # radix index compares these at the diverging block for partial hits
    prefix_keys: List[BlockKey] = field(default_factory=list)
    cached_tokens: int = 0             # prompt tokens served from the cache

    @property
    def in_prefill(self) -> bool:
        return self.prefill_done < self.prompt_len


@dataclass
class ScheduledChunk:
    req_id: int
    start: int                         # first prompt position in this chunk
    length: int                        # real tokens in the chunk


@dataclass
class StepPlan:
    prefill_chunks: List[ScheduledChunk] = field(default_factory=list)
    decode_req_ids: List[int] = field(default_factory=list)
    admitted: List[int] = field(default_factory=list)
    preempted: List[int] = field(default_factory=list)
    # (src, dst) device page copies the engine must apply before prefill:
    # dst is a private copy of shared cached page src (copy-on-write)
    cow_pairs: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return (sum(c.length for c in self.prefill_chunks)
                + len(self.decode_req_ids))


class Scheduler:
    def __init__(self, kv: PagedKVConfig, max_batch: int,
                 token_budget: int = 256, chunk_size: int = 64,
                 enable_preemption: bool = False,
                 enable_prefix_cache: bool = False,
                 prefix_index: str = "radix",
                 min_partial_tokens: int = 1):
        self.kv = kv
        self.max_batch = max_batch
        self.token_budget = token_budget
        self.chunk_size = chunk_size
        self.enable_preemption = enable_preemption
        self.enable_prefix_cache = enable_prefix_cache
        self.min_partial_tokens = min_partial_tokens
        self.allocator = PageAllocator(
            kv.num_pages, enable_prefix_cache=enable_prefix_cache,
            index_kind=prefix_index, page_size=kv.page_size)
        self.tables = BlockTableStore(kv)
        self.waiting: Deque[SeqState] = deque()
        self.running: Dict[int, SeqState] = {}
        self._free_slots = list(range(max_batch - 1, -1, -1))
        self.preemptions = 0
        # per-stage prefix-cache hit accounting (surfaced by the engine).
        # cached_tokens = full_block_tokens + partial_tokens; partial
        # tokens are served through a copy-on-write page (a partial-block
        # radix hit, or the final page of a fully-cached aligned prompt)
        self.prefix_stats = {"lookups": 0, "hits": 0,
                             "cached_tokens": 0, "computed_tokens": 0,
                             "full_block_tokens": 0, "partial_tokens": 0,
                             "partial_hits": 0}

    # ------------------------------------------------------------------
    def add(self, req_id: int, prompt_len: int, sampling: SamplingParams,
            block_hashes: Optional[List[BlockHash]] = None,
            prefix_keys: Optional[List[BlockKey]] = None) -> None:
        self.waiting.append(SeqState(req_id, prompt_len, sampling,
                                     block_hashes=block_hashes or [],
                                     prefix_keys=prefix_keys or []))

    def set_hashes(self, req_id: int, hashes: List[BlockHash],
                   keys: Optional[List[BlockKey]] = None) -> None:
        """Replace a running request's block-hash chain (the engine extends
        it over generated tokens just before release, so whole finished
        contexts become matchable by later multi-turn requests)."""
        seq = self.running[req_id]
        seq.block_hashes = hashes
        if keys is not None:
            seq.prefix_keys = keys

    def add_prefilled(self, req_id: int, prompt_len: int,
                      sampling: SamplingParams) -> None:
        """Admit a request whose prompt KV was computed by a remote prefill
        stage (PD disaggregation): no prefill chunks are scheduled; the
        engine injects the transferred KV on admission."""
        self.waiting.append(SeqState(req_id, prompt_len, sampling,
                                     prefill_done=prompt_len,
                                     generated=1, pos=prompt_len))

    def _admission_pages(self, seq: SeqState) -> int:
        """Pages reserved at admission. With preemption the pool grows
        incrementally during decode (vLLM-style); without it, the full
        prompt+max_new worth is reserved upfront so admission can't
        deadlock mid-decode."""
        if self.enable_preemption:
            tokens = seq.prompt_len
        else:
            tokens = seq.prompt_len + seq.sampling.max_new_tokens
        return min(pages_for(tokens, self.kv.page_size),
                   self.kv.max_pages_per_seq)

    def prefix_hint(self, block_hashes: Optional[List[BlockHash]],
                    prefix_keys: Optional[List[BlockKey]] = None) -> int:
        """Cache-affinity probe: matched *tokens* of ``block_hashes`` (+
        partial-block sub-keys) resident in this replica's radix index.
        Read-only and cross-thread safe — the router scores replicas with
        it."""
        if not (self.enable_prefix_cache and block_hashes):
            return 0
        return self.allocator.prefix_hint(block_hashes, prefix_keys)

    def _match_prefix(self, seq: SeqState, total: int):
        """Longest cached prefix usable by ``seq``: (pages, cow).

        Full pages strictly before the last prompt token are reused as-is.
        ``cow`` is ``None`` or ``(src_page, m)``: the next block partially
        matches a cached page for m leading tokens, which the engine
        materializes by copying src into a private page and recomputing
        only positions >= m.  Two cases collapse into one mechanism:

          - radix partial-block hit: the diverging block shares its first
            m tokens with a cached sibling block (m < page, or m < the
            request's tail length for the final block);
          - fully-cached page-aligned prompt: every block matched, but at
            least one token must be recomputed to produce logits, so the
            final page is reused via CoW with m = page - 1.

        Both clamp m so cached_tokens <= prompt_len - 1."""
        page = self.kv.page_size
        matched, partial = self.allocator.match(seq.block_hashes,
                                                seq.prefix_keys)
        k_full = min((seq.prompt_len - 1) // page, total - 1)
        cow = None
        if len(matched) > k_full:
            # fully-cached aligned prompt: recompute only the last token
            cow = (matched[k_full], page - 1)
        elif partial is not None:
            j = len(matched)
            m = min(partial[1], seq.prompt_len - 1 - j * page)
            if m >= self.min_partial_tokens:
                cow = (partial[0], m)
        return matched[:k_full], cow

    def _admit_one(self, seq: SeqState, plan: StepPlan) -> bool:
        page = self.kv.page_size
        total = self._admission_pages(seq)
        cached: List[int] = []
        cow = None
        looked_up = (self.enable_prefix_cache and seq.block_hashes
                     and seq.prefill_done == 0)
        if looked_up:
            cached, cow = self._match_prefix(seq, total)
            self.prefix_stats["lookups"] += 1
        # take refs on the hit pages (and pin the CoW source so it cannot
        # be evicted before the engine copies it) BEFORE allocating fresh
        # pages: allocation may evict refcount-0 cached pages
        pins = cached + ([cow[0]] if cow is not None else [])
        self.allocator.acquire(seq.req_id, pins)
        fresh = self.allocator.allocate(seq.req_id, total - len(cached))
        if fresh is None:
            self.allocator.free(seq.req_id)    # roll back the acquisitions
            return False                       # FIFO: head waits, no skips
        full_tokens = len(cached) * page
        part_tokens = 0
        if cow is not None:
            plan.cow_pairs.append((cow[0], fresh[0]))
            part_tokens = cow[1]
        seq.cached_tokens = full_tokens + part_tokens
        if seq.cached_tokens:
            self.prefix_stats["hits"] += 1
            seq.prefill_done = seq.cached_tokens
            seq.pos = seq.cached_tokens
        if part_tokens:
            self.prefix_stats["partial_hits"] += 1
        if looked_up:
            self.prefix_stats["cached_tokens"] += seq.cached_tokens
            self.prefix_stats["full_block_tokens"] += full_tokens
            self.prefix_stats["partial_tokens"] += part_tokens
            self.prefix_stats["computed_tokens"] += (seq.prompt_len
                                                     - seq.cached_tokens)
        seq.slot = self._free_slots.pop()
        self.tables.set(seq.req_id, cached + fresh)
        self.running[seq.req_id] = seq
        plan.admitted.append(seq.req_id)
        return True

    def _try_admit(self, plan: StepPlan) -> None:
        while self.waiting and self._free_slots:
            if not self._admit_one(self.waiting[0], plan):
                break                   # FIFO: don't skip ahead of the head
            self.waiting.popleft()

    def _preempt(self, victim: SeqState, plan: StepPlan) -> None:
        """Recompute-mode preemption: free the victim's pages + slot and
        push it to the front of the waiting queue for re-prefill."""
        rid = victim.req_id
        self.running.pop(rid)
        if self.enable_prefix_cache and victim.block_hashes:
            # publish the victim's full, KV-complete pages before freeing
            # them: free() then parks them in the LRU instead of the free
            # list, so the re-admission's _match_prefix re-acquires the
            # victim's own prefix instead of recomputing it (and any other
            # request sharing the prefix hits too)
            n_full = min(len(victim.block_hashes),
                         victim.pos // self.kv.page_size)
            table = self.tables.tables.get(rid, [])
            self.allocator.publish(table[:n_full],
                                   victim.block_hashes[:n_full],
                                   victim.prefix_keys[:n_full] or None)
        self.allocator.free(rid)
        self.tables.drop(rid)
        self._free_slots.append(victim.slot)
        plan.preempted.append(rid)
        # reset for recompute: generated tokens (minus the last sampled one,
        # whose KV was never written) join the prompt; the engine extends
        # the prompt embeddings and skips the prefill-completion sample
        victim.slot = -1
        victim.prefill_done = 0
        victim.pos = 0
        if victim.generated >= 1:
            victim.prompt_len += victim.generated - 1
            victim.resumed = True
        self.waiting.appendleft(victim)
        self.preemptions += 1

    def _ensure_decode_capacity(self, plan: StepPlan) -> None:
        """Incremental page growth for running decodes; on OOM, preempt the
        youngest running request so the oldest always makes progress
        (age-ordered eviction can't thrash)."""
        for seq in sorted(self.running.values(), key=lambda s: s.req_id):
            if seq.req_id not in self.running or seq.finished \
                    or seq.in_prefill:
                continue
            # grow against the block TABLE length: owned pages can include
            # a CoW pin that is not addressable through the table
            while (pages_for(seq.pos + 1, self.kv.page_size)
                   > len(self.tables.tables.get(seq.req_id, []))):
                got = self.allocator.allocate(seq.req_id, 1)
                if got is not None:
                    self.tables.extend(seq.req_id, got)
                    continue
                victims = [s for s in self.running.values()
                           if not s.finished and s.req_id > seq.req_id]
                if victims:
                    self._preempt(max(victims, key=lambda s: s.req_id), plan)
                else:
                    self._preempt(seq, plan)     # evict itself; retry later
                    break

    def schedule(self) -> StepPlan:
        """Plan one engine step."""
        plan = StepPlan()
        self._try_admit(plan)
        if self.enable_preemption:
            self._ensure_decode_capacity(plan)
        budget = self.token_budget
        # decodes first (latency-critical; never dropped)
        for seq in self.running.values():
            if not seq.in_prefill and not seq.finished:
                plan.decode_req_ids.append(seq.req_id)
        budget -= len(plan.decode_req_ids)
        # prefill chunks with the remaining budget
        for seq in self.running.values():
            if budget <= 0:
                break
            if seq.in_prefill:
                n = min(self.chunk_size, seq.prompt_len - seq.prefill_done,
                        max(budget, 0))
                if n > 0:
                    plan.prefill_chunks.append(
                        ScheduledChunk(seq.req_id, seq.prefill_done, n))
                    budget -= n
        return plan

    # ------------------------------------------------------------------
    def note_prefill(self, req_id: int, n: int) -> None:
        seq = self.running[req_id]
        seq.prefill_done += n
        seq.pos = seq.prefill_done      # pos = #tokens whose KV is written

    def note_decode_written(self, req_id: int) -> None:
        """One decode step wrote this request's current token KV at seq.pos."""
        self.running[req_id].pos += 1

    def note_sampled(self, req_id: int, token: int) -> bool:
        """Record one sampled token; returns True if the request finished."""
        seq = self.running[req_id]
        seq.generated += 1
        sp = seq.sampling
        if (seq.generated >= sp.max_new_tokens
                or (sp.eos_token >= 0 and token == sp.eos_token)
                or seq.pos + 1 >= self.kv.max_seq):
            seq.finished = True
        return seq.finished

    def release(self, req_id: int) -> None:
        seq = self.running.pop(req_id)
        if self.enable_prefix_cache and seq.block_hashes:
            # publish the finished request's full, KV-complete pages into
            # the index; free() then parks refcount-0 hashed pages in the
            # LRU instead of the free list, so later arrivals can hit them
            n_full = min(len(seq.block_hashes),
                         seq.pos // self.kv.page_size)
            table = self.tables.tables.get(req_id, [])
            self.allocator.publish(table[:n_full],
                                   seq.block_hashes[:n_full],
                                   seq.prefix_keys[:n_full] or None)
        self.allocator.free(req_id)
        self.tables.drop(req_id)
        self._free_slots.append(seq.slot)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)
