"""Radix-tree prefix index over chained block hashes (SGLang-style).

The tree replaces the flat content-hash -> page map of the page allocator.
Each node owns one physical KV page; an edge is one *block* (page_size
tokens) keyed by its chained hash ``hash_i = H(hash_{i-1}, contents_i)``.
Because hashes chain, a node's hash uniquely identifies the entire prefix
ending at it, so the tree is also probeable as a flat dict (``_by_hash``)
— one O(1) probe per block, O(match length) per walk — while the tree
structure adds what the flat map cannot do:

  - **partial-block hits**: every node may carry per-token sub-keys (token
    ids for tokenized stages, per-row digests for embed-fed stages).  At
    the first diverging block the walk compares the request's sub-keys
    against each *child* of the deepest matched node and returns the child
    with the longest common token prefix.  Soundness: KV at position p
    depends only on tokens 0..p, and the chained hash match guarantees the
    contexts before the block are identical, so the first m rows of that
    child's page are exactly the KV a fresh prefill would compute — the
    scheduler materializes them through copy-on-write and recomputes only
    the tail.
  - **leaf-ordered eviction**: eviction scans the allocator's LRU oldest
    first but only takes a page whose node is a *leaf*, never an interior
    node with live descendants (removing an interior page would orphan its
    subtree and break prefix closure).  Because requests always acquire
    contiguous prefixes from the root, refcounts are monotone
    non-increasing along any root-to-leaf path; hence whenever the LRU is
    non-empty some leaf is in it and eviction always makes progress.
  - **prefix closure**: an indexed block implies every ancestor block is
    indexed (leaf-only eviction preserves this), which is what makes the
    dict-probe walk and the cross-thread ``hint`` sound.
  - **snapshot paths**: root-to-leaf chains (hashes, sub-keys, pages) that
    a sibling replica can pin, extract KV from, and seed into a freshly
    scaled-up engine (warm scale-up).

``FlatIndex`` keeps the PR-6 flat-map behavior behind the same interface
as the ablation baseline (full-block hits only, pure-LRU eviction, no
snapshot) for the differential tests and ``benchmarks/bench_radix.py``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

BlockHash = Tuple[str, bytes]
# per-token sub-keys within one block: a tuple of hashables (ints for token
# stages, bytes row-digests for embed stages); the final block of a prompt
# may carry fewer than page_size entries
BlockKey = Tuple
# a partial-block hit: (page holding the partially matching block, number
# of leading tokens of that block that match the request)
PartialHit = Tuple[int, int]


def _common_prefix(a: Sequence, b: Sequence) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class RadixNode:
    __slots__ = ("hash", "page", "key", "parent", "children")

    def __init__(self, h: Optional[BlockHash], page: int,
                 key: Optional[BlockKey], parent: Optional["RadixNode"]):
        self.hash = h
        self.page = page
        self.key = key
        self.parent = parent
        self.children: Dict[BlockHash, "RadixNode"] = {}


class RadixIndex:
    """Radix tree mapping chained block-hash prefixes to KV pages."""

    def __init__(self) -> None:
        self._root = RadixNode(None, -1, None, None)
        self._by_hash: Dict[BlockHash, RadixNode] = {}
        self._by_page: Dict[int, RadixNode] = {}

    def __len__(self) -> int:
        return len(self._by_page)

    def __contains__(self, h: BlockHash) -> bool:
        return h in self._by_hash

    def has_page(self, page: int) -> bool:
        return page in self._by_page

    def pages(self) -> Iterable[int]:
        return self._by_page.keys()

    # -- insert ---------------------------------------------------------
    def insert(self, hashes: Sequence[BlockHash], pages: Sequence[int],
               keys: Optional[Sequence[Optional[BlockKey]]] = None) -> int:
        """Insert a full root-anchored chain.  First writer wins per node:
        an existing node keeps its page (the caller's duplicate page stays
        unindexed).  The walk stops if a *new* node would need a page that
        is already indexed elsewhere (it cannot back two nodes).  Returns
        the number of nodes created."""
        cur = self._root
        created = 0
        for i, (h, p) in enumerate(zip(hashes, pages)):
            key = keys[i] if keys is not None and i < len(keys) else None
            node = cur.children.get(h)
            if node is None:
                if h in self._by_hash or p in self._by_page:
                    break                      # conflicting registration
                node = RadixNode(h, p, key, cur)
                cur.children[h] = node
                self._by_hash[h] = node
                self._by_page[p] = node
                created += 1
            elif node.key is None and key is not None:
                node.key = key                 # backfill sub-keys
            cur = node
        return created

    # -- lookup ---------------------------------------------------------
    def lookup(self, hashes: Iterable[BlockHash]) -> List[int]:
        """Pages of the longest indexed full-block prefix (walk from the
        root, O(match length))."""
        out: List[int] = []
        cur = self._root
        for h in hashes:
            node = cur.children.get(h)
            if node is None:
                break
            out.append(node.page)
            cur = node
        return out

    def match(self, hashes: Sequence[BlockHash],
              keys: Optional[Sequence[Optional[BlockKey]]] = None,
              ) -> Tuple[List[int], Optional[PartialHit]]:
        """Longest full-block prefix plus the best partial hit at the
        diverging block.

        ``keys`` aligns with the request's blocks (``keys[j]`` are the
        per-token sub-keys of block j; the final entry may cover a partial
        tail block, so ``len(keys)`` may exceed ``len(hashes)``).  At the
        first miss at depth j the children of the deepest matched node are
        scored by common sub-key prefix against ``keys[j]``; ties prefer
        the smallest page id (deterministic).  The chained-hash match up
        to j guarantees both contexts agree before the block, so the first
        m rows of the winning child's page are byte-identical to a fresh
        prefill's KV."""
        out: List[int] = []
        cur = self._root
        depth = 0
        for h in hashes:
            node = cur.children.get(h)
            if node is None:
                break
            out.append(node.page)
            cur = node
            depth += 1
        partial: Optional[PartialHit] = None
        target = keys[depth] if keys and depth < len(keys) else None
        if target:
            for child in cur.children.values():
                if not child.key:
                    continue
                m = _common_prefix(child.key, target)
                if m > 0 and (partial is None or m > partial[1]
                              or (m == partial[1]
                                  and child.page < partial[0])):
                    partial = (child.page, m)
        return out, partial

    def hint(self, hashes: Sequence[BlockHash],
             keys: Optional[Sequence[Optional[BlockKey]]],
             page_size: int) -> int:
        """Matched-token count for cache-affinity routing.  Read-only and
        cross-thread tolerant: the full-block walk is one dict probe per
        block (sound because leaf-only eviction keeps the index
        prefix-closed), and the partial-block probe is advisory — if the
        owning engine mutates the tree mid-iteration we keep the
        full-block score."""
        n = 0
        for h in hashes:
            if h not in self._by_hash:
                break
            n += 1
        score = n * page_size
        try:
            _, partial = self.match(hashes[:n], keys)
            if partial is not None:
                score += partial[1]
        except RuntimeError:            # children mutated during iteration
            pass
        return score

    # -- eviction -------------------------------------------------------
    def pick_evictable(self, lru: Iterable[int]) -> Optional[int]:
        """Coldest evictable page: the first page in LRU order whose node
        is a leaf.  Interior nodes with live descendants are skipped —
        evicting one would orphan its subtree."""
        for p in lru:
            node = self._by_page.get(p)
            if node is None or not node.children:
                return p
        return None

    def remove(self, page: int) -> None:
        node = self._by_page.pop(page)
        assert not node.children, "evicting an interior radix node"
        del self._by_hash[node.hash]
        del node.parent.children[node.hash]

    # -- snapshot (warm scale-up) ---------------------------------------
    def paths(self, max_pages: int = 0,
              ) -> List[Tuple[List[BlockHash], List[Optional[BlockKey]],
                              List[int]]]:
        """Root-to-leaf chains as (hashes, keys, pages), deepest first,
        greedily truncated once ``max_pages`` distinct pages are covered
        (0 = no cap).  Shared prefixes repeat across paths; the consumer
        deduplicates via its own lookup before seeding."""
        out = []
        stack: List[Tuple[RadixNode, List[RadixNode]]] = [(self._root, [])]
        while stack:
            node, trail = stack.pop()
            kids = list(node.children.values())
            if node is not self._root:
                trail = trail + [node]
                if not kids:
                    out.append(trail)
            stack.extend((c, trail) for c in kids)
        out.sort(key=len, reverse=True)
        paths, seen = [], set()
        for trail in out:
            if max_pages and len(seen) >= max_pages:
                break
            seen.update(n.page for n in trail)
            paths.append(([n.hash for n in trail],
                          [n.key for n in trail],
                          [n.page for n in trail]))
        return paths

    # -- invariants -----------------------------------------------------
    def check(self) -> bool:
        """Structural invariants: hash/page bijection through the same
        nodes, parent/child link consistency, and every node reachable
        from the root (prefix closure)."""
        if len(self._by_hash) != len(self._by_page):
            return False
        seen = 0
        stack = list(self._root.children.values())
        while stack:
            node = stack.pop()
            seen += 1
            if self._by_hash.get(node.hash) is not node:
                return False
            if self._by_page.get(node.page) is not node:
                return False
            if node.parent.children.get(node.hash) is not node:
                return False
            stack.extend(node.children.values())
        return seen == len(self._by_hash)


class FlatIndex:
    """PR-6 flat content-hash -> page map behind the RadixIndex interface:
    full-block hits only, strict-LRU eviction order, no partial matches,
    no snapshot paths.  Kept as the ablation baseline."""

    def __init__(self) -> None:
        self._hash_to_page: Dict[BlockHash, int] = {}
        self._page_hash: Dict[int, BlockHash] = {}

    def __len__(self) -> int:
        return len(self._page_hash)

    def __contains__(self, h: BlockHash) -> bool:
        return h in self._hash_to_page

    def has_page(self, page: int) -> bool:
        return page in self._page_hash

    def pages(self) -> Iterable[int]:
        return self._page_hash.keys()

    def insert(self, hashes: Sequence[BlockHash], pages: Sequence[int],
               keys: Optional[Sequence[Optional[BlockKey]]] = None) -> int:
        created = 0
        for h, p in zip(hashes, pages):
            if h in self._hash_to_page or p in self._page_hash:
                continue
            self._hash_to_page[h] = p
            self._page_hash[p] = h
            created += 1
        return created

    def lookup(self, hashes: Iterable[BlockHash]) -> List[int]:
        out: List[int] = []
        for h in hashes:
            p = self._hash_to_page.get(h)
            if p is None:
                break
            out.append(p)
        return out

    def match(self, hashes: Sequence[BlockHash],
              keys: Optional[Sequence[Optional[BlockKey]]] = None,
              ) -> Tuple[List[int], Optional[PartialHit]]:
        return self.lookup(hashes), None

    def hint(self, hashes: Sequence[BlockHash],
             keys: Optional[Sequence[Optional[BlockKey]]],
             page_size: int) -> int:
        n = 0
        for h in hashes:
            if h not in self._hash_to_page:
                break
            n += 1
        return n * page_size

    def pick_evictable(self, lru: Iterable[int]) -> Optional[int]:
        for p in lru:
            return p
        return None

    def remove(self, page: int) -> None:
        h = self._page_hash.pop(page)
        del self._hash_to_page[h]

    def paths(self, max_pages: int = 0):
        return []                      # no chain structure to snapshot

    def check(self) -> bool:
        return (len(self._hash_to_page) == len(self._page_hash)
                and all(self._hash_to_page.get(h) == p
                        for p, h in self._page_hash.items()))


def make_index(kind: str):
    if kind == "radix":
        return RadixIndex()
    if kind == "flat":
        return FlatIndex()
    raise ValueError(f"unknown prefix index kind: {kind!r}")
