"""Token sampling for AR stages: greedy / temperature / top-k."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 => greedy
    top_k: int = 0                     # 0 => no top-k filter
    eos_token: int = -1                # -1 => never stops early


import functools


@functools.partial(jax.jit, static_argnames=("temperature", "top_k"))
def sample_tokens(logits: jax.Array, temperature: float, top_k: int,
                  key: jax.Array) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
