"""Monolithic baseline: the HF-Transformers-style execution the paper
compares against (§4.1 "Baseline Systems").

One request at a time, stages co-located and executed sequentially via
end-to-end generate() calls: no continuous batching, no chunked prefill,
no paged KV, no streaming overlap. Uses the same model weights as the
disaggregated pipeline so the comparison is apples-to-apples.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.sampling import sample_tokens
from repro.models import transformer as T
from repro.models.dit import sample as dit_sample


class MonolithicQwenOmni:
    """Sequential Thinker -> Talker -> Vocoder, one request at a time."""

    def __init__(self, bundle: dict, vocoder, max_seq: int = 256,
                 dit_steps: int = 8, seed: int = 0):
        self.b = bundle
        self.vocoder = vocoder          # (cfg, params) for the DiT vocoder
        self.max_seq = max_seq
        self.dit_steps = dit_steps
        self._key = jax.random.PRNGKey(seed)
        self._jit: Dict[str, object] = {}

    def _generate(self, cfg, params, prompt_embeds, n_new, extra_embeds=None):
        """Naive generate(): full prefill then one-by-one decode, batch=1."""
        kname = cfg.name
        if kname not in self._jit:
            cfg2 = cfg.replace(modality="audio_frames")

            def prefill(p, emb):
                return T.forward_prefill(cfg2, p, emb, self.max_seq,
                                         remat=False)

            def decode(p, cache, emb, pos):
                return T.forward_decode(cfg2, p, cache, emb, pos)
            self._jit[kname] = (jax.jit(prefill), jax.jit(decode))
        prefill, decode = self._jit[kname]

        emb = jnp.asarray(prompt_embeds)[None]
        logits, cache = prefill(params, emb)
        pos = prompt_embeds.shape[0]
        toks, hiddens = [], []
        self._key, sk = jax.random.split(self._key)
        tok = int(sample_tokens(logits[:, -1], 0.8, 20, sk)[0])
        toks.append(tok)
        for i in range(n_new - 1):
            e = params["embed"][jnp.asarray([[tok]])]
            if extra_embeds is not None:
                j = min(i, extra_embeds.shape[0] - 1)
                e = e + jnp.asarray(extra_embeds[j])[None, None]
            logits, cache = decode(params, cache, e, jnp.array([pos]))
            pos += 1
            self._key, sk = jax.random.split(self._key)
            tok = int(sample_tokens(logits[:, 0], 0.8, 20, sk)[0])
            toks.append(tok)
        return np.array(toks, np.int32)

    def _thinker_hidden(self, cfg, params, tokens):
        # baseline recomputes hidden states with a second full forward
        # (the transformers implementation extracts them from generate())
        cfg2 = cfg
        emb = params["embed"][jnp.asarray(tokens)][None]
        logits, _ = T.forward_full(cfg2.replace(modality="audio_frames"),
                                   params, emb, remat=False)
        h = emb  # tiny proxy: hidden ~= embeddings for the baseline path
        return np.asarray(h[0])

    def run(self, requests: List[np.ndarray]) -> List[dict]:
        """requests: list of prompt token arrays. Returns per-request
        results with timings (sequential JCTs accumulate queueing delay,
        as in offline HF inference)."""
        b = self.b
        results = []
        t_start = time.perf_counter()
        for toks in requests:
            t0 = time.perf_counter()
            pe = np.asarray(b["thinker_params"]["embed"][jnp.asarray(toks)])
            text = self._generate(b["thinker_cfg"], b["thinker_params"], pe,
                                  b["thinker_tokens"])
            t_think = time.perf_counter()
            th = self._thinker_hidden(b["thinker_cfg"], b["thinker_params"],
                                      text)
            codec = self._generate(b["talker_cfg"], b["talker_params"], th,
                                   b["talker_tokens"], extra_embeds=th)
            t_talk = time.perf_counter()
            cond = jnp.asarray(b["codec_embed"][codec])[None]
            vcfg, vparams = self.vocoder
            self._key, sk = jax.random.split(self._key)
            wav = dit_sample(vcfg, vparams, cond, cond.shape[1] * 2, sk,
                             num_steps=self.dit_steps)
            wav = np.asarray(wav)
            t_end = time.perf_counter()
            results.append({
                "text": text, "codec": codec, "wave": wav,
                "jct": t_end - t_start,      # from batch submission
                "exec": t_end - t0,
                "thinker_time": t_think - t0,
                "talker_time": t_talk - t_think,
                "vocoder_time": t_end - t_talk,
            })
        return results
