"""Event-driven per-stage-worker backend: concurrency, backpressure,
drain/shutdown lifecycle, online-arrival metrics.

Uses pure-python stub engines (no jax) so these run in the fast tier."""
import threading
import time

import pytest

from repro.core.config import ServeConfig
from repro.core.graph import StageGraph
from repro.core.metrics import summarize, summarize_queueing
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request, StageEvent
from repro.core.stage import StageEngine, StageSpec


class StubEngine:
    """One finished event per queued item, optional per-step dwell."""

    def __init__(self, name, delay=0.0):
        self.name = name
        self.delay = delay
        self.q = []
        self.busy_time = 0.0
        self.finish_times = {}           # req_id -> perf_counter at finish

    def enqueue(self, req_id, inputs, sampling, data):
        self.q.append((req_id, dict(inputs)))

    @property
    def has_work(self):
        return bool(self.q)

    @property
    def queue_depth(self):
        return len(self.q)

    def step(self):
        if not self.q:
            return []
        t0 = time.perf_counter()
        if self.delay:
            time.sleep(self.delay)
        rid, inp = self.q.pop(0)
        self.busy_time += time.perf_counter() - t0
        self.finish_times[rid] = time.perf_counter()
        return [StageEvent(rid, "finished", {"x": inp.get("x", 0) + 1},
                           stage=self.name)]


class CountdownEngine(StubEngine):
    """Continuous-batching stub: each request carries its own step count,
    so a late-arriving cheap request finishes before an early costly one."""

    def step(self):
        if not self.q:
            return []
        events = []
        still = []
        for rid, inp in self.q:
            inp["work"] = inp.get("work", 1) - 1
            if inp["work"] <= 0:
                self.finish_times[rid] = time.perf_counter()
                events.append(StageEvent(rid, "finished", {"x": 1},
                                         stage=self.name))
            else:
                still.append((rid, inp))
        self.q = still
        time.sleep(0.001)
        return events


class ChunkSourceEngine(StubEngine):
    """Streams n chunk events per request, then the terminal finished
    event (n_chunks set, so streaming edges skip forwarding it)."""

    def __init__(self, name, n_chunks=5):
        super().__init__(name)
        self.n_chunks = n_chunks

    def step(self):
        if not self.q:
            return []
        rid, _ = self.q.pop(0)
        evs = [StageEvent(rid, "chunk", {"x": i}, stage=self.name,
                          chunk_index=i, is_last=(i == self.n_chunks - 1))
               for i in range(self.n_chunks)]
        evs.append(StageEvent(rid, "finished", {"n_chunks": self.n_chunks},
                              stage=self.name))
        self.finish_times[rid] = time.perf_counter()
        return evs


class ChunkSinkEngine(StubEngine):
    """Records the per-request arrival order of streamed chunks."""

    def __init__(self, name):
        super().__init__(name)
        self.order = {}                  # req_id -> [chunk_index, ...]

    def enqueue(self, req_id, inputs, sampling, data):
        self.order.setdefault(req_id, []).append(inputs["chunk_index"])
        self.q.append((req_id, dict(inputs)))

    def step(self):
        if not self.q:
            return []
        rid, inp = self.q.pop(0)
        if inp.get("is_last_chunk"):
            self.finish_times[rid] = time.perf_counter()
            return [StageEvent(rid, "finished",
                               {"n": len(self.order[rid])},
                               stage=self.name)]
        return []


def _chain(*engines, capacity=64):
    graph = StageGraph()
    for i, eng in enumerate(engines):
        graph.add_stage(StageSpec(eng.name, "custom",
                                  is_output=(i == len(engines) - 1)))
    for up, dn in zip(engines, engines[1:]):
        graph.add_edge(up.name, dn.name, lambda d, p: {"x": p["x"]})
    return Orchestrator(graph, {e.name: e for e in engines},
                        config=ServeConfig(queue_capacity=capacity))


def test_stub_engines_satisfy_protocol():
    assert isinstance(StubEngine("s"), StageEngine)


def test_fast_stage_not_serialized_behind_slow_stage():
    """The disaggregation claim itself: with per-stage workers, a fast
    upstream stage churns through ALL requests while the slow downstream
    stage is still on its first — under lock-step, each fast step would be
    separated by a full slow dwell."""
    fast, slow = StubEngine("fast"), StubEngine("slow", delay=0.05)
    orch = _chain(fast, slow)
    reqs = [Request(inputs={"x": 0}) for _ in range(5)]
    orch.start()
    for r in reqs:
        orch.submit(r)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(r.completion_time is not None and not r.failed for r in reqs)
    last_fast = max(fast.finish_times.values())
    first_slow = min(slow.finish_times.values())
    # lock-step would put ~4 slow dwells (200ms) before the last fast finish
    assert last_fast < first_slow + 0.02, \
        "fast stage must not be serialized behind the slow stage"


def test_out_of_order_completion_across_stages():
    gen, sink = CountdownEngine("gen"), StubEngine("sink")
    orch = _chain(gen, sink)
    costly = Request(inputs={"work": 40})
    cheap = Request(inputs={"work": 1})
    orch.start()
    orch.submit(costly)
    orch.submit(cheap)
    first = orch.completions.get(timeout=30.0)
    second = orch.completions.get(timeout=30.0)
    orch.shutdown()
    assert first.req_id == cheap.req_id, "cheap request must finish first"
    assert second.req_id == costly.req_id
    assert first.completion_time < second.completion_time


def test_bounded_inbox_backpressure():
    fast, slow = StubEngine("fast"), StubEngine("slow", delay=0.01)
    orch = _chain(fast, slow, capacity=2)
    reqs = [Request(inputs={"x": 0}) for _ in range(10)]
    for r in reqs:
        orch.submit(r)
    done = orch.run(timeout=60.0)
    assert len(done) == 10
    assert orch.stage_metrics()["slow"]["max_inbox_depth"] <= 2
    assert orch.edge_stats["fast->slow"]["transfers"] == 10
    # the router measurably waited on the bounded queue at least once
    assert orch.edge_stats["fast->slow"]["backpressure_s"] > 0


def test_drain_shutdown_and_restart():
    a, b = StubEngine("a"), StubEngine("b", delay=0.002)
    orch = _chain(a, b)
    orch.start()
    reqs = [Request(inputs={"x": 0}) for _ in range(4)]
    for r in reqs:
        orch.submit(r)
    # drain=True cascades topo-order: upstream finals flush downstream
    orch.shutdown(drain=True)
    assert all(r.completion_time is not None for r in reqs)
    assert all(not w.alive for w in orch._workers.values())
    orch.shutdown()                              # idempotent
    # restart serves new requests through fresh worker threads
    more = [Request(inputs={"x": 0}) for _ in range(2)]
    orch.start()
    for r in more:
        orch.submit(r)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(r.outputs["b"] for r in more)
    # metrics object survived the restart and kept accumulating
    assert orch.stage_metrics()["a"]["admitted"] == 6


def test_online_arrivals_record_queueing_metrics():
    a, b = StubEngine("a"), StubEngine("b", delay=0.005)
    orch = _chain(a, b)
    orch.start()
    reqs = []
    for k in range(6):
        reqs.append(Request(inputs={"x": k}))
        orch.submit(reqs[-1])
        time.sleep(0.002)                        # staggered arrivals
    # streaming consumption: completions arrive while later ones serve
    got = [orch.completions.get(timeout=30.0) for _ in range(6)]
    orch.shutdown()
    assert {r.req_id for r in got} == {r.req_id for r in reqs}
    m = summarize(reqs, wall_time=1.0)
    assert m["n"] == 6 and m["jct_p50"] > 0 and m["ttft_p50"] > 0
    qd = summarize_queueing(reqs)
    assert set(qd) == {"a", "b"} and qd["b"]["p95"] >= 0
    sm = orch.stage_metrics()
    assert sm["a"]["admitted"] == 6 and sm["b"]["finished"] == 6
    assert sm["b"]["queue_delay_p95"] >= sm["b"]["queue_delay_p50"] >= 0
    assert sm["b"]["busy_time"] > 0


def test_transfer_failure_isolated_threaded():
    a, b = StubEngine("a"), StubEngine("b")
    graph = StageGraph()
    graph.add_stage(StageSpec("a", "custom"))
    graph.add_stage(StageSpec("b", "custom", is_output=True))

    def flaky(data, payload):
        if data.get("poison"):
            raise RuntimeError("boom")
        return {"x": payload["x"]}

    graph.add_edge("a", "b", flaky)
    orch = Orchestrator(graph, {"a": a, "b": b})
    orch.start()
    good = Request(inputs={"x": 0})
    bad = Request(inputs={"x": 0}, data={"poison": True})
    orch.submit(bad)
    orch.submit(good)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert bad.failed is not None and "boom" in bad.failed
    assert good.failed is None and good.outputs["b"]


def test_tick_rejected_while_threaded_backend_runs():
    a = StubEngine("a")
    graph = StageGraph()
    graph.add_stage(StageSpec("a", "custom", is_output=True))
    orch = Orchestrator(graph, {"a": a})
    orch.start()
    with pytest.raises(RuntimeError, match="lock-step"):
        orch.tick()
    orch.shutdown()
    # after shutdown the lock-step path works again
    orch.submit(Request(inputs={"x": 0}))
    orch.tick()


def test_streaming_chunk_fifo_per_request():
    """Chunk ordering across the connector boundary: every streamed chunk
    is stamped with a per-(edge, request) sequence number and the
    destination worker asserts strictly-increasing delivery — so the sink
    observes each request's chunks in exactly the emitted order, with no
    violations counted, and the per-request counters are reclaimed."""
    src, sink = ChunkSourceEngine("src", n_chunks=6), ChunkSinkEngine("sink")
    graph = StageGraph()
    graph.add_stage(StageSpec("src", "custom"))
    graph.add_stage(StageSpec("sink", "custom", is_output=True))
    graph.add_edge("src", "sink", lambda d, p: {"x": p["x"]},
                   streaming=True)
    orch = Orchestrator(graph, {"src": src, "sink": sink})
    reqs = [Request(inputs={"x": 0}) for _ in range(4)]
    orch.start()
    for r in reqs:
        orch.submit(r)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    for r in reqs:
        assert not r.failed
        assert sink.order[r.req_id] == list(range(6))
    assert orch.stage_metrics()["sink"]["order_violations"] == 0
    assert not orch._edge_seq, "seq counters must be reclaimed on finish"


def test_out_of_order_chunk_dropped_and_counted():
    """A duplicate or reordered chunk seq at one worker is a protocol
    violation: the item is dropped (never enqueued), the violation and an
    error event are recorded.  A forward gap stays legal (replica handoff
    mid-stream), and seq_last reclaims the tracker entry."""
    from repro.core.worker import StageInput, StageWorker
    eng = StubEngine("s")
    events = []
    w = StageWorker("s", eng, lambda stage, ev: events.append(ev))
    req = Request(inputs={})
    sp = object()
    w._admit(StageInput(req, sp, inputs={"x": 0}, seq=0))
    w._admit(StageInput(req, sp, inputs={"x": 1}, seq=1))
    w._admit(StageInput(req, sp, inputs={"x": 2}, seq=1))    # duplicate
    w._admit(StageInput(req, sp, inputs={"x": 3}, seq=0))    # reorder
    assert len(eng.q) == 2, "violating chunks must not reach the engine"
    assert w.metrics.order_violations == 2
    errs = [e for e in events if e.kind == "error"]
    assert len(errs) == 2
    assert all("out-of-order" in e.payload["error"] for e in errs)
    # a gap is legal (strictly increasing, not +1): replica handoff
    w._admit(StageInput(req, sp, inputs={"x": 4}, seq=5, seq_last=True))
    assert len(eng.q) == 3
    assert req.req_id not in w._last_seq, "seq_last frees the tracker"


def test_sync_backend_matches_old_lockstep_semantics():
    fast, slow = StubEngine("fast"), StubEngine("slow", delay=0.0)
    graph = StageGraph()
    graph.add_stage(StageSpec("fast", "custom"))
    graph.add_stage(StageSpec("slow", "custom", is_output=True))
    graph.add_edge("fast", "slow", lambda d, p: {"x": p["x"]})
    orch = Orchestrator(graph, {"fast": fast, "slow": slow}, backend="sync")
    reqs = [Request(inputs={"x": 1}) for _ in range(3)]
    for r in reqs:
        orch.submit(r)
    done = orch.run()
    assert len(done) == 3
    assert all(r.outputs["slow"][0]["x"] == 3 for r in reqs)   # 1 +1 +1


def test_worker_metrics_counters_are_thread_safe():
    """Regression: chunk order violations and engine errors used to be
    bare `+=` on shared counters from worker threads; the locked note_*
    methods must not lose increments under contention."""
    from repro.core.worker import WorkerMetrics
    m = WorkerMetrics()
    n_threads, k = 8, 400

    def hammer():
        for _ in range(k):
            m.note_error()
            m.note_filtered()
            m.note_order_violation()     # bumps order_violations AND errors
            m.note_steps(2)
            m.note_event(StageEvent(0, "finished", {"x": 1}, stage="s"))

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = m.snapshot()
    total = n_threads * k
    assert snap["errors"] == 2 * total
    assert snap["filtered"] == total
    assert snap["order_violations"] == total
    assert snap["steps"] == 2 * total
    assert snap["events"] == total and snap["finished"] == total


class FlakyConnector:
    """Connector stub whose recv() times out for chosen requests (the
    transfer key embeds the req_id as its middle path segment)."""

    def __init__(self, fail_req_ids):
        self.fail_req_ids = set(fail_req_ids)
        self.resident = {}
        self.released = []

    def send(self, key, payload):
        self.resident[key] = payload

    def recv(self, key, timeout=None):
        from repro.connector.base import TransferTimeout
        req_id = int(key.rsplit("/", 2)[1])
        if req_id in self.fail_req_ids:
            raise TransferTimeout(key, connector="flaky", timeout=timeout)
        return self.resident[key]

    def release(self, key):
        self.resident.pop(key, None)
        self.released.append(key)

    @property
    def stats(self):
        return {}


def test_sync_transfer_failure_fails_request_and_releases_key():
    """Regression: a connector error on the sync (lock-step) path used to
    escape run() and kill the drain loop; it must fail only the owning
    request, and the transfer key's lifetime must end either way."""
    a, b = StubEngine("a"), StubEngine("b")
    graph = StageGraph()
    graph.add_stage(StageSpec("a", "custom"))
    graph.add_stage(StageSpec("b", "custom", is_output=True))
    graph.add_edge("a", "b", lambda d, p: {"x": p["x"]}, connector="flaky")
    bad = Request(inputs={"x": 0})
    good = Request(inputs={"x": 0})
    conn = FlakyConnector(fail_req_ids={bad.req_id})
    orch = Orchestrator(graph, {"a": a, "b": b}, backend="sync",
                        connectors={"flaky": conn})
    orch.submit(bad)
    orch.submit(good)
    done = orch.run()
    assert bad.failed is not None and "timed out" in bad.failed
    assert good.failed is None and good.outputs["b"]
    assert {r.req_id for r in done} == {bad.req_id, good.req_id}
    # every sent key was released, including the failed transfer's
    assert conn.resident == {}
    assert sorted(conn.released) == sorted(
        k for k in conn.released)  # no double-free bookkeeping surprises
    assert len(conn.released) == 2
