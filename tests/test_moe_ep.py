"""Expert-parallel MoE (shard_map) must match the GSPMD path numerically.

Runs in a subprocess with 8 forced host devices so the main test process
keeps its single-device jax state.
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.models import moe
from repro.sharding.context import DistContext, distribution

cfg = get_config("qwen3_moe_30b_a3b", smoke=True).replace(
    dtype="float32", capacity_factor=1e9)          # lossless: exact match
key = jax.random.PRNGKey(0)
p = moe.init_moe(cfg, key)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

y_ref, aux_ref = moe.moe_forward(cfg, p, x)        # single-device GSPMD path

mesh = jax.make_mesh((2, 4), ("data", "model"))
with distribution(DistContext(mesh=mesh, moe_impl="ep")):
    with mesh:
        y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_forward(cfg, p, x))(p, x)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-4)
# The aux (load-balance) losses are DIFFERENT estimators, equal only in
# expectation: the EP path computes sum(frac*prob) per data shard over its
# T_loc=16 local tokens and pmeans across shards (per-device capacity
# semantics, see moe_ep.py), while the GSPMD reference computes one global
# sum over all 32 tokens.  The gap is the cross-shard covariance of
# (frac, prob), O(1/T_loc) relative — observed ~3e-4 absolute on aux~1e-2.
# 2e-3 bounds that estimator gap while still catching real routing bugs
# (a double-count or missing psum shifts aux by >1e-2).
assert abs(float(aux_ep) - float(aux_ref)) < 2e-3, (aux_ep, aux_ref)
print("EP-OK")
"""


def test_ep_matches_gspmd():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "EP-OK" in r.stdout, r.stdout + r.stderr
