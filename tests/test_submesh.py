"""Per-stage accelerator allocation (paper Fig 3(c)): carving stage
submeshes out of the global mesh. Subprocess with 8 forced host devices."""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_stage_submesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
# allocate model-axis devices 0..2 to the thinker, 2..4 to the talker
thinker_mesh = make_stage_submesh(mesh, "model", 0, 2)
talker_mesh = make_stage_submesh(mesh, "model", 2, 4)
dt = {d.id for d in thinker_mesh.devices.flat}
dk = {d.id for d in talker_mesh.devices.flat}
assert dt.isdisjoint(dk), (dt, dk)
assert dt | dk == {d.id for d in mesh.devices.flat}
assert thinker_mesh.axis_names == mesh.axis_names

# each stage jits onto ITS OWN submesh
def stage_fn(w, x):
    return x @ w
w = jnp.ones((16, 16)); x = jnp.ones((4, 16))
for m in (thinker_mesh, talker_mesh):
    with m:
        out = jax.jit(stage_fn,
                      in_shardings=(NamedSharding(m, P(None, "model")),
                                    NamedSharding(m, P("data", None))),
                      )(w, x)
        devs = {d.id for d in out.sharding.device_set}
        assert devs <= {d.id for d in m.devices.flat}
print("SUBMESH-OK")
"""


def test_stage_submesh_allocation():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SUBMESH-OK" in r.stdout, r.stdout + r.stderr
