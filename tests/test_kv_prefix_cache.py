"""Refcounted prefix-cache allocator + cache-aware scheduler invariants.

Pure host-side structures (no jitted model work) — this module is in the
fast tier.  Hypothesis property tests run under the conftest shim when
hypothesis is installed; the deterministic random-walk versions always
run so the invariants are exercised offline too.
"""
import random

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.engine.kv_cache import (PageAllocator, PagedKVConfig,
                                   hash_embed_blocks, hash_token_blocks)
from repro.engine.sampling import SamplingParams
from repro.engine.scheduler import Scheduler

PAGE = 8


def _hashes(tokens):
    return hash_token_blocks(tokens, PAGE)


# ---------------------------------------------------------------------------
# allocator unit tests
# ---------------------------------------------------------------------------

def test_acquire_publish_release_cycle():
    a = PageAllocator(8, enable_prefix_cache=True)
    h = _hashes(list(range(16)))               # 2 full pages
    p1 = a.allocate(1, 3)
    a.publish(p1[:2], h)
    assert a.lookup(h) == p1[:2]
    a.free(1)
    # published pages park in the LRU; the unhashed one is free
    assert a.cached_pages == 2 and a.free_pages == 6
    assert a.check_invariant()
    # a second request re-acquires them (refcount 0 -> 1)
    a.acquire(2, a.lookup(h))
    assert a.refcount(p1[0]) == 1 and a.cached_pages == 0
    # a third shares them (refcount 2)
    a.acquire(3, a.lookup(h))
    assert a.refcount(p1[0]) == 2
    a.free(2)
    assert a.refcount(p1[0]) == 1 and a.check_invariant()
    a.free(3)
    assert a.cached_pages == 2 and a.reusable_pages == 8
    assert a.check_invariant()


def test_lru_eviction_frees_cached_pages_only():
    a = PageAllocator(4, enable_prefix_cache=True)
    h = _hashes(list(range(24)))               # 3 pages
    pages = a.allocate(1, 3)
    a.publish(pages, h)
    a.free(1)
    assert a.cached_pages == 3
    # allocating past the free list evicts oldest cached pages
    got = a.allocate(2, 3)
    assert got is not None and a.check_invariant()
    assert a.cached_pages <= 1 and a.evictions >= 2
    # referenced pages are never evictable: pool is now 3 referenced +
    # at most 1 cached — asking for 2 more must fail, not evict
    assert a.allocate(3, 2) is None
    assert a.check_invariant()


def test_eviction_preserves_acquired_prefix():
    a = PageAllocator(4, enable_prefix_cache=True)
    h = _hashes(list(range(16)))
    pages = a.allocate(1, 2)
    a.publish(pages, h)
    a.free(1)
    a.acquire(2, a.lookup(h))           # re-acquired: refcount 1
    a.allocate(3, 2)                    # exhausts the free list
    assert a.allocate(4, 1) is None     # nothing evictable remains
    assert a.lookup(h) == pages         # the acquired prefix survived
    assert a.check_invariant()


def test_cow_gives_private_copy_and_pins_source():
    a = PageAllocator(6, enable_prefix_cache=True)
    h = _hashes(list(range(8)))
    pages = a.allocate(1, 1)
    a.publish(pages, h)
    a.free(1)
    src = a.lookup(h)[0]
    a.acquire(2, [src])
    dst = a.cow(2, src)
    assert dst is not None and dst != src
    assert a.refcount(src) == 1 and a.refcount(dst) == 1
    assert a.check_invariant()
    # the source stays cached after the holder releases
    a.free(2)
    assert a.lookup(h) == [src] and a.cached_pages == 1
    assert a.check_invariant()


def test_publish_dedupes_first_writer_wins():
    a = PageAllocator(8, enable_prefix_cache=True)
    h = _hashes(list(range(8)))
    p1 = a.allocate(1, 1)
    p2 = a.allocate(2, 1)
    a.publish(p1, h)
    a.publish(p2, h)                    # duplicate content: ignored
    assert a.lookup(h) == p1
    a.free(1)
    a.free(2)
    # the duplicate went straight back to the free list
    assert a.cached_pages == 1 and a.free_pages == 7
    assert a.check_invariant()


def test_disabled_cache_matches_legacy_allocator():
    a = PageAllocator(10)
    p1 = a.allocate(1, 4)
    p2 = a.allocate(2, 6)
    assert p1 and p2 and a.free_pages == 0
    assert a.allocate(3, 1) is None
    a.publish(p1, _hashes(list(range(32))))    # no-op when disabled
    a.free(1)
    assert a.free_pages == 4 and a.cached_pages == 0
    assert a.check_invariant()


def test_hash_chains_are_prefix_consistent():
    toks = list(range(40))
    full = _hashes(toks)
    assert _hashes(toks[:16]) == full[:2]      # chain property
    assert _hashes([1] + toks[1:])[0] != full[0]
    assert len(full) == 40 // PAGE
    import numpy as np
    e = np.arange(32 * 4, dtype=np.float32).reshape(32, 4)
    he = hash_embed_blocks(e, PAGE)
    assert len(he) == 4 and he == hash_embed_blocks(e.copy(), PAGE)
    # token and embed hashes can never collide (kind-tagged)
    assert all(a != b for a in full for b in he)


# ---------------------------------------------------------------------------
# random-walk property: conservation, no double free, no eviction of
# referenced pages under arbitrary acquire/share/release/evict/CoW mixes
# ---------------------------------------------------------------------------

def _allocator_walk(seed: int, num_pages: int, steps: int) -> None:
    r = random.Random(seed)
    a = PageAllocator(num_pages, enable_prefix_cache=True)
    live = {}                                  # req_id -> published hashes
    next_req = 0
    for _ in range(steps):
        op = r.random()
        if op < 0.35 or not live:              # new request: hit + allocate
            rid = next_req
            next_req += 1
            toks = [r.randrange(3) for _ in range(r.randrange(0, 4 * PAGE))]
            hashes = _hashes(toks)
            hit = a.lookup(hashes)
            a.acquire(rid, hit)
            want = r.randrange(1, 4)
            got = a.allocate(rid, want)
            if got is None:
                a.free(rid)                    # admission rollback
                continue
            if hit and r.random() < 0.5:       # CoW the last shared page
                a.cow(rid, hit[-1])
            # publish the whole root-anchored chain (prefix nodes already
            # exist and keep their pages; the fresh suffix attaches deeper)
            n_pub = min(len(got), max(0, len(hashes) - len(hit)))
            n_chain = len(hit) + n_pub
            a.publish(hit + got[:n_pub], hashes[:n_chain])
            live[rid] = True
        elif op < 0.8:                         # release a random request
            rid = r.choice(list(live))
            del live[rid]
            a.free(rid)
        else:                                  # churn: force evictions
            filler = -1
            got = a.allocate(filler, r.randrange(1, num_pages))
            if got is not None:
                a.free(filler)
        assert a.check_invariant(), f"invariant broken (seed={seed})"
    for rid in list(live):
        a.free(rid)
    assert a.check_invariant()
    assert a.reusable_pages == num_pages       # pool fully conserved


def test_allocator_random_walk_deterministic():
    for seed in range(25):
        _allocator_walk(seed, num_pages=12, steps=120)


@given(st.integers(0, 10_000), st.integers(6, 24), st.integers(20, 200))
@settings(max_examples=50, deadline=None)
def test_allocator_random_walk(seed, num_pages, steps):
    _allocator_walk(seed, num_pages, steps)


# ---------------------------------------------------------------------------
# cache-aware scheduler: shared prompts hit, FIFO holds, pool conserved
# ---------------------------------------------------------------------------

def _drive(sched, prompts_hashes, max_new=3):
    admitted = []
    for i, (plen, hashes) in enumerate(prompts_hashes):
        sched.add(i, plen, SamplingParams(max_new_tokens=max_new),
                  block_hashes=hashes)
    for _ in range(5000):
        if not sched.has_work:
            break
        plan = sched.schedule()
        assert sched.allocator.check_invariant()
        admitted.extend(plan.admitted)
        if not plan.prefill_chunks and not plan.decode_req_ids:
            break
        for ch in plan.prefill_chunks:
            sched.note_prefill(ch.req_id, ch.length)
            if not sched.running[ch.req_id].in_prefill:
                if sched.note_sampled(ch.req_id, 0):
                    sched.release(ch.req_id)
        for rid in list(plan.decode_req_ids):
            if rid in sched.running and not sched.running[rid].finished:
                sched.note_decode_written(rid)
                if sched.note_sampled(rid, 1):
                    sched.release(rid)
    return admitted


def _scheduler_walk(seed: int, n_reqs: int) -> None:
    r = random.Random(seed)
    kv = PagedKVConfig(num_pages=48, page_size=PAGE, max_pages_per_seq=8)
    sched = Scheduler(kv, max_batch=4, token_budget=32, chunk_size=PAGE,
                      enable_prefix_cache=True)
    families = [[r.randrange(100) for _ in range(3 * PAGE)]
                for _ in range(2)]
    prompts = []
    for _ in range(n_reqs):
        fam = r.choice(families)
        cut = r.randrange(1, len(fam) + 1)
        toks = fam[:cut] + [r.randrange(100, 200)
                            for _ in range(r.randrange(0, PAGE))]
        prompts.append((len(toks), _hashes(toks)))
    admitted = _drive(sched, prompts)
    assert admitted == sorted(admitted), "cache hits must not break FIFO"
    assert not sched.running and not sched.waiting
    # drained: every page free or parked (cached) — nothing leaked
    assert sched.allocator.reusable_pages == kv.num_pages
    assert sched.allocator.check_invariant()


def test_scheduler_prefix_walk_deterministic():
    for seed in range(20):
        _scheduler_walk(seed, n_reqs=12)


@given(st.integers(0, 10_000), st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_scheduler_prefix_walk(seed, n_reqs):
    _scheduler_walk(seed, n_reqs)


def test_scheduler_shared_prompt_hits_and_cow():
    kv = PagedKVConfig(num_pages=32, page_size=PAGE, max_pages_per_seq=8)
    sched = Scheduler(kv, max_batch=2, token_budget=64, chunk_size=PAGE,
                      enable_prefix_cache=True)
    toks = list(range(2 * PAGE))               # exactly page-aligned
    _drive(sched, [(len(toks), _hashes(toks))])
    st0 = dict(sched.prefix_stats)
    assert st0["hits"] == 0 and st0["computed_tokens"] == 2 * PAGE
    # identical page-aligned prompt: full hit via CoW, one token recomputed
    sched.add(1, len(toks), SamplingParams(max_new_tokens=3),
              block_hashes=_hashes(toks))
    plan = sched.schedule()
    assert plan.admitted == [1] and len(plan.cow_pairs) == 1
    seq = sched.running[1]
    assert seq.cached_tokens == 2 * PAGE - 1
    assert seq.prefill_done == seq.pos == 2 * PAGE - 1
    # the CoW copy is private; the shared source is not in the table
    src, dst = plan.cow_pairs[0]
    table = sched.tables.tables[1]
    assert dst in table and src not in table
    assert sched.allocator.refcount(src) == 1   # pinned until release
    assert sched.allocator.check_invariant()
    # only the suffix (1 token here) is left to prefill
    assert sum(c.length for c in plan.prefill_chunks) == 1
    sched.note_prefill(1, 1)
    assert not sched.running[1].in_prefill
    sched.note_sampled(1, 0)
    sched.release(1)
    assert sched.allocator.reusable_pages == kv.num_pages
    assert sched.allocator.check_invariant()


def test_scheduler_partial_prefix_hit():
    kv = PagedKVConfig(num_pages=32, page_size=PAGE, max_pages_per_seq=8)
    sched = Scheduler(kv, max_batch=2, token_budget=64, chunk_size=PAGE,
                      enable_prefix_cache=True)
    shared = list(range(2 * PAGE))
    _drive(sched, [(2 * PAGE + 3, _hashes(shared + [7, 8, 9]))])
    # same 2-page prefix, different tail
    sched.add(1, 2 * PAGE + 5, SamplingParams(max_new_tokens=2),
              block_hashes=_hashes(shared + [1, 2, 3, 4, 5]))
    plan = sched.schedule()
    assert plan.admitted == [1] and not plan.cow_pairs
    assert sched.running[1].cached_tokens == 2 * PAGE
    assert sched.prefix_stats["hits"] == 1
    assert sched.allocator.check_invariant()


def test_prefix_cache_off_never_caches():
    kv = PagedKVConfig(num_pages=32, page_size=PAGE, max_pages_per_seq=8)
    sched = Scheduler(kv, max_batch=2, token_budget=64, chunk_size=PAGE)
    toks = list(range(2 * PAGE))
    _drive(sched, [(len(toks), _hashes(toks)),
                   (len(toks), _hashes(toks))])
    assert sched.prefix_stats["lookups"] == 0
    assert sched.allocator.free_pages == kv.num_pages
    assert sched.allocator.cached_pages == 0


def test_preempt_publishes_pages_for_reacquisition():
    """A preemption victim's KV-complete pages are published before they
    are freed, so its re-admission re-acquires its own prefix through
    ``_match_prefix`` instead of recomputing the whole prompt."""
    kv = PagedKVConfig(num_pages=12, page_size=PAGE, max_pages_per_seq=12)
    sched = Scheduler(kv, max_batch=4, enable_preemption=True,
                      enable_prefix_cache=True)
    toks = list(range(6 * PAGE))                 # 6 full hashed blocks
    sched.add(0, 5 * PAGE, SamplingParams(max_new_tokens=2))   # no hashes
    sched.add(1, 6 * PAGE, SamplingParams(max_new_tokens=8),
              block_hashes=_hashes(toks))
    plan = sched.schedule()
    assert plan.admitted == [0, 1]               # 5 + 6 pages, 1 free
    for rid, n in ((0, 5 * PAGE), (1, 6 * PAGE)):
        sched.note_prefill(rid, n)
        sched.note_sampled(rid, 0)
    # decode growth: 0 takes the last free page; 1 finds the pool empty
    # and (no younger victim) preempts itself
    plan = sched.schedule()
    assert plan.preempted == [1]
    # all 6 KV-complete pages were published, not dropped on the floor
    assert sched.allocator.cached_pages == 6
    # prefix_hint scores matched TOKENS (radix: partial blocks count too)
    assert sched.prefix_hint(_hashes(toks)) == 6 * PAGE
    assert sched.allocator.check_invariant()
    # finish 0 so its pages free up (unhashed: straight to the free list)
    sched.note_decode_written(0)
    assert sched.note_sampled(0, 0)
    sched.release(0)
    # re-admission: the victim hits its own published prefix — the whole
    # page-aligned prompt via CoW, only the last token is recomputed
    plan = sched.schedule()
    assert plan.admitted == [1] and len(plan.cow_pairs) == 1
    seq = sched.running[1]
    assert seq.resumed
    assert seq.cached_tokens == 6 * PAGE - 1
    assert sched.prefix_stats["hits"] == 1       # first admission missed
    assert sched.allocator.check_invariant()
    sched.release(1)
    assert sched.allocator.reusable_pages == kv.num_pages
    assert sched.allocator.check_invariant()
