"""A broken transfer function fails only ITS request — other in-flight
requests complete normally (production fault isolation)."""
import numpy as np

from repro.configs.pipelines import build_qwen_omni
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def test_transfer_failure_isolated():
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=3,
                                        talker_tokens=6, dit_steps=2)
    # sabotage the thinker->talker transfer for ONE request id
    edge = next(e for e in graph.edges if e.src == "thinker")
    orig = edge.transfer
    victim = {}

    def flaky(data, payload):
        if data.get("poison"):
            raise RuntimeError("boom")
        return orig(data, payload)
    edge.transfer = flaky

    orch = Orchestrator(graph, engines)
    good = [Request(inputs={"tokens": np.arange(6, dtype=np.int32)})
            for _ in range(2)]
    bad = Request(inputs={"tokens": np.arange(6, dtype=np.int32)},
                  data={"poison": True})
    for r in (good[0], bad, good[1]):
        orch.submit(r)
    done = orch.run()
    assert bad.failed is not None and "boom" in bad.failed
    assert bad.completion_time is not None
    for r in good:
        assert r.failed is None
        assert r.outputs.get("vocoder"), "healthy requests must complete"
    assert len(done) == 3
