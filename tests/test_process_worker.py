"""Process-isolated stage replicas: cross-process shared-memory
transport, spawn lifecycle (start/drain/stop), replica-death re-admission
and connector-routed warm seeding.

Children run jax-free stub engines rebuilt from picklable EngineSpecs,
so every test here is a sub-second spawn plus stub work — fast tier.
Spawn start is exercised for real: this module IS the <15s process-
isolation smoke that `make check` runs.
"""
import os
import signal
import time

import numpy as np
import pytest

from repro.connector import shm_transport
from repro.connector.shm import SharedMemoryConnector
from repro.core.config import EngineSpec, ServeConfig, StageConfig
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.core.worker import StageInput, ReplicaSet
from repro.engine.stub_engine import StubEngine


def _spawn_ok() -> bool:
    if not shm_transport.available():
        return False
    try:
        import multiprocessing as mp
        mp.get_context("spawn")
        return True
    except Exception:                    # noqa: BLE001
        return False


pytestmark = pytest.mark.skipif(
    not _spawn_ok(), reason="spawn multiprocessing or shared_memory "
                            "unavailable on this platform")

STUB = EngineSpec("repro.engine.stub_engine:make_stub",
                  {"name": "s", "dwell_ms": 1.0})


def _graph():
    g = StageGraph()
    g.add_stage(StageSpec("s", "custom", is_output=True))
    return g


# ---------------------------------------------------------------------------
# cross-process shared-memory roundtrip
# ---------------------------------------------------------------------------

def _shm_echo_child(manifest, q):
    """Spawn target: rebuild the payload in another process, unlink the
    segment (ownership passed with the manifest), echo scalars back."""
    payload = shm_transport.read_and_release(manifest)
    q.put({"sum": float(payload["x"].sum()),
           "shape": tuple(payload["x"].shape),
           "tag": payload["meta"]["tag"]})


def test_shm_roundtrip_crosses_processes():
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    x = np.arange(24, dtype=np.float32).reshape(4, 6)
    seg, manifest = shm_transport.write_segment(
        {"x": x, "meta": {"tag": "hello"}})
    assert seg is not None and manifest.nbytes == x.nbytes
    seg.close()                          # child unlinks via the manifest
    q = ctx.Queue()
    p = ctx.Process(target=_shm_echo_child, args=(manifest, q))
    p.start()
    out = q.get(timeout=30)
    p.join(10)
    assert out == {"sum": float(x.sum()), "shape": (4, 6), "tag": "hello"}
    # the receiving side released the segment: re-attach must fail
    with pytest.raises(FileNotFoundError):
        shm_transport.read_manifest(manifest)


def test_release_manifest_is_idempotent():
    seg, manifest = shm_transport.write_segment(
        {"x": np.ones(8, np.float32)})
    seg.close()
    shm_transport.release_manifest(manifest)
    shm_transport.release_manifest(manifest)     # second release: no-op


# ---------------------------------------------------------------------------
# orchestrator end-to-end: process stage serves identically to thread
# ---------------------------------------------------------------------------

def _run_pipeline(isolation):
    stages = {"s": StageConfig(replicas=2, isolation=isolation,
                               engine_spec=STUB,
                               engine_factory=lambda: STUB.build())}
    orch = Orchestrator(_graph(), {"s": StubEngine("s")},
                        config=ServeConfig(stages=stages))
    reqs = [Request(inputs={"x": i}) for i in range(8)]
    for r in reqs:
        orch.submit(r)
    done = orch.run(timeout=60.0)
    assert len(done) == 8 and not any(r.failed for r in done)
    return sorted(r.outputs["s"][0]["x"] for r in done), orch


def test_process_stage_matches_thread_outputs():
    out_thread, _ = _run_pipeline("thread")
    out_proc, orch = _run_pipeline("process")
    assert out_proc == out_thread == list(range(8))
    m = orch.stage_metrics()["s"]
    assert m["admitted"] == m["finished"] == 8
    assert m["errors"] == 0 and m["replica_failures"] == 0
    assert m["n_replicas"] == 2


def test_pre_start_admission_is_deferred_then_served():
    stages = {"s": StageConfig(isolation="process", engine_spec=STUB)}
    orch = Orchestrator(_graph(), {"s": StubEngine("s")},
                        config=ServeConfig(stages=stages))
    # submit BEFORE start(): a process stage has no parent-side engine
    # to step, so admission defers and flushes through the worker
    orch.submit(Request(inputs={"x": 41}))
    done = orch.run(timeout=60.0)
    assert len(done) == 1 and done[0].outputs["s"][0]["x"] == 41


# ---------------------------------------------------------------------------
# lifecycle: drain loses nothing; killed replica re-admits in-flight work
# ---------------------------------------------------------------------------

def test_drain_stops_losing_nothing():
    spec = EngineSpec("repro.engine.stub_engine:make_stub",
                      {"name": "s", "dwell_ms": 20.0})
    events = []
    rs = ReplicaSet("s", [None], lambda st, ev: events.append(ev),
                    isolation="process", engine_spec=spec)
    rs.start()
    assert rs.workers()[0][1].wait_ready(30.0)
    for i in range(10):
        assert rs.submit(StageInput(Request(inputs={"x": i}), None,
                                    inputs={"x": i}), timeout=10.0)
    rs.stop(drain=True)
    rs.join(60.0)
    finished = [e for e in events if e.kind == "finished"]
    assert len(finished) == 10
    assert not [e for e in events if e.kind == "error"]


def test_killed_replica_readmits_to_survivor():
    spec = EngineSpec("repro.engine.stub_engine:make_stub",
                      {"name": "s", "dwell_ms": 30.0})
    events = []
    rs = ReplicaSet("s", [None, None], lambda st, ev: events.append(ev),
                    isolation="process", engine_spec=spec,
                    process_opts={"heartbeat_timeout": 5.0})
    rs.start()
    for _, w in rs.workers():
        assert w.wait_ready(30.0)
    reqs = [Request(inputs={"x": i}) for i in range(12)]
    for r in reqs:
        assert rs.submit(StageInput(r, None, inputs=r.inputs), timeout=10.0)
    time.sleep(0.05)                     # let work start flowing
    victim = rs.workers()[0][1]
    os.kill(victim._proc.pid, signal.SIGKILL)
    deadline = time.time() + 30.0
    while time.time() < deadline:
        if len({e.req_id for e in events if e.kind == "finished"}) == 12:
            break
        time.sleep(0.05)
    rs.stop(drain=True)
    rs.join(30.0)
    finished = {e.req_id for e in events if e.kind == "finished"}
    assert finished == {r.req_id for r in reqs}          # zero lost
    assert not [e for e in events if e.kind == "error"]
    assert rs.n_replicas == 1                            # survivor only
    assert len(rs.failure_events) == 1
    fe = rs.failure_events[0]
    assert fe["reason"] == "process exited" and fe["readmitted"] >= 1
    # the failure is visible in the banked worker metrics
    assert sum(m.snapshot()["replica_failures"]
               for m in rs.metrics_bank.values()) == 1


# ---------------------------------------------------------------------------
# warm seeding routed through the connector channel API
# ---------------------------------------------------------------------------

def _seed_pages(n):
    return [{"hash": i, "k": np.full((4, 8), i, np.float32),
             "v": np.full((4, 8), -i, np.float32)} for i in range(n)]


def test_scale_up_warm_seeds_over_connector():
    spec = EngineSpec("repro.engine.stub_engine:make_seedable",
                      {"name": "s", "pages": 0})
    conn = SharedMemoryConnector(cross_process=True)
    rs = ReplicaSet("s", [None], lambda st, ev: None,
                    isolation="process", engine_spec=spec,
                    seed_connector=conn)
    rs.start()
    w0 = rs.workers()[0][1]
    assert w0.wait_ready(30.0)
    assert w0.seed_snapshot(_seed_pages(6)) == 6         # warm the donor
    rid = rs.scale_up()
    try:
        assert rs.seed_events == [{"rid": rid, "donor_pages": 6,
                                   "pages": 6, "via": "manifest"}]
        snap = rs._replicas[rid].prefix_snapshot()
        assert len(snap) == 6
        for p in snap:                   # byte-equivalent to the donor's
            assert np.array_equal(
                p["k"], np.full((4, 8), p["hash"], np.float32))
            assert np.array_equal(
                p["v"], np.full((4, 8), -p["hash"], np.float32))
    finally:
        rs.stop()
        rs.join(30.0)
    assert conn.resident_bytes == 0      # seed payload fully released


def test_warm_seed_failure_degrades_to_cold_start():
    class RefusingConnector(SharedMemoryConnector):
        def send(self, key, payload, **kw):
            raise RuntimeError("transport down")

    spec = EngineSpec("repro.engine.stub_engine:make_seedable",
                      {"name": "s", "pages": 0})
    rs = ReplicaSet("s", [None], lambda st, ev: None,
                    isolation="process", engine_spec=spec,
                    seed_connector=RefusingConnector(cross_process=True))
    rs.start()
    w0 = rs.workers()[0][1]
    assert w0.wait_ready(30.0)
    assert w0.seed_snapshot(_seed_pages(3)) == 3
    rid = rs.scale_up()                  # advisory: must not raise
    try:
        assert rs.n_replicas == 2
        assert rs._replicas[rid].prefix_snapshot() == []     # cold start
    finally:
        rs.stop()
        rs.join(30.0)
