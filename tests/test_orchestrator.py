"""End-to-end stage-graph serving tests (tiny Qwen-Omni pipeline)."""
import numpy as np
import pytest

from repro.configs.pipelines import (build_ar_dit, build_mimo_audio,
                                     build_qwen_omni)
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def _prompts(n, lo=6, hi=20, vocab=500, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=rng.integers(lo, hi)).astype(np.int32)
            for _ in range(n)]


@pytest.fixture(scope="module")
def omni():
    return build_qwen_omni(max_batch=4, thinker_tokens=6, talker_tokens=18,
                           stream_chunk=6, dit_steps=2)


def test_omni_pipeline_completes(omni):
    graph, engines, bundle = omni
    orch = Orchestrator(graph, engines)
    for p in _prompts(3):
        orch.submit(Request(inputs={"tokens": p}))
    done = orch.run()
    assert len(done) == 3
    for r in done:
        assert r.jct is not None and r.jct > 0
        assert "thinker_hidden" in r.data
        assert r.data["thinker_hidden"].shape == (6, 128)
        chunks = r.outputs["vocoder"]
        assert len(chunks) == 3            # 18 talker tokens / 6 per chunk
        total = sum(c["latent"].shape[0] for c in chunks)
        assert total == 18 * 2             # out_len_per_cond = 2
        # per-stage spans recorded for the decomposition benchmark
        for st in ("thinker", "talker", "vocoder"):
            assert r.stage_time(st) >= 0


def test_streaming_overlaps_stages(omni):
    """First vocoder chunk must be produced before the talker finishes."""
    graph, engines, bundle = build_qwen_omni(
        max_batch=2, thinker_tokens=4, talker_tokens=24, stream_chunk=6,
        dit_steps=2)
    orch = Orchestrator(graph, engines)
    orch.submit(Request(inputs={"tokens": np.arange(8, dtype=np.int32)}))
    first_voc_chunk_tick = None
    talker_done_tick = None
    for tick in range(2000):
        busy = any(engines[n].has_work for n in graph.stages)
        for name in graph.topo_order():
            for ev in engines[name].step():
                ev.stage = ev.stage or name
                if name == "vocoder" and first_voc_chunk_tick is None:
                    first_voc_chunk_tick = tick
                if name == "talker" and ev.kind == "finished":
                    talker_done_tick = tick
                orch._route(ev)
        if not busy:
            break
    assert first_voc_chunk_tick is not None and talker_done_tick is not None
    assert first_voc_chunk_tick < talker_done_tick, \
        "streaming must overlap vocoder with talker decoding"


def test_multimodal_inputs_via_mm_encode(omni):
    """Audio/image frontend embeddings (stubbed) flow through the Thinker's
    mm_encode preprocess and extend its prompt (paper Fig 4)."""
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=4,
                                        talker_tokens=8, dit_steps=2)
    rng = np.random.default_rng(3)
    req = Request(inputs={"tokens": np.arange(6, dtype=np.int32)},
                  data={"mm_embeds": rng.standard_normal(
                      (10, 32)).astype(np.float32)})
    orch = Orchestrator(graph, engines)
    orch.submit(req)
    done = orch.run()
    assert len(done) == 1
    assert req.data["mm_frames_used"] == 10
    assert req.outputs["vocoder"]


def test_connector_stats_populated(omni):
    graph, engines, bundle = omni
    orch = Orchestrator(graph, engines)
    orch.submit(Request(inputs={"tokens": np.arange(10, dtype=np.int32)}))
    orch.run()
    stats = orch.connector_stats()
    assert stats["shm"].calls >= 1          # thinker->talker hidden states
    assert stats["inline"].calls >= 1       # talker->vocoder chunks
    assert stats["shm"].bytes > 0


def test_ar_dit_pipeline():
    graph, engines, _ = build_ar_dit("glm", max_batch=2, ar_tokens=5,
                                     image_latents=16, dit_steps=2)
    orch = Orchestrator(graph, engines)
    for p in _prompts(2, seed=1):
        orch.submit(Request(inputs={"tokens": p}))
    done = orch.run()
    assert len(done) == 2
    for r in done:
        img = r.outputs["glm_dit"][0]["latent"]
        assert img.shape == (16, 32)
        assert np.isfinite(img).all()


def test_mimo_pipeline():
    graph, engines, _ = build_mimo_audio(max_batch=2, ar_tokens=6, patch=4)
    orch = Orchestrator(graph, engines)
    rng = np.random.default_rng(0)
    for _ in range(2):
        orch.submit(Request(
            inputs={"audio": rng.standard_normal((32, 16)).astype(np.float32)}))
    done = orch.run()
    assert len(done) == 2
    for r in done:
        audio = r.outputs["patch_dec"][0]["audio"]
        assert audio.shape == (6, 64)       # 6 tokens * patch(4)*16


def test_disaggregated_beats_nothing_lost():
    """All requests complete even when arrival exceeds batch capacity."""
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=3,
                                        talker_tokens=6, stream_chunk=0,
                                        dit_steps=2)
    orch = Orchestrator(graph, engines)
    for p in _prompts(7, seed=2):
        orch.submit(Request(inputs={"tokens": p}))
    done = orch.run()
    assert len(done) == 7
