"""Fixture corpus for the invariant analyzer (``tools/analyze``).

For every rule code there is a bad fixture proving the rule fires, an
automated check that ``# noqa: CODE`` on the flagged line suppresses it
(and that a *different* code does not), and a check that a baseline
entry keyed on the finding absorbs it.  A self-scan test asserts the
repo itself is clean modulo the committed baseline, so the ``make
check`` gate stays green by construction.

Pure-python AST work, no jax — fast tier."""
import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:          # `tools` lives at the repo root
    sys.path.insert(0, str(REPO))

from tools.analyze import (Baseline, BaselineEntry, analyze_paths,  # noqa: E402
                           analyze_source, is_suppressed, noqa_codes)
from tools.analyze.__main__ import main as analyze_main  # noqa: E402


# ---------------------------------------------------------------------------
# fixture corpus: one bad snippet per rule code
# ---------------------------------------------------------------------------

CCY001_BAD = """\
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0                  # guarded-by: _lock

    def bump(self):
        self.value += 1

    def peek(self):
        return self.value
"""

CCY001_GOOD = """\
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0                  # guarded-by: _lock

    def bump(self):
        with self._lock:
            self.value += 1
"""

CCY001_REQUIRES_BAD = """\
import threading

class Pool:
    def __init__(self):
        self._lock = threading.Lock()

    def _evict_one(self):               # requires-lock: _lock
        pass

    def trim(self):
        self._evict_one()
"""

CCY002_BAD = """\
import threading

class Pair:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def ab(self):
        with self._a:
            with self._b:
                pass

    def ba(self):
        with self._b:
            with self._a:
                pass
"""

CCY002_SELF_DEADLOCK = """\
import threading

class Once:
    def __init__(self):
        self._lock = threading.Lock()

    def twice(self):
        with self._lock:
            with self._lock:
                pass
"""

CCY002_RLOCK_OK = """\
import threading

class Once:
    def __init__(self):
        self._lock = threading.RLock()

    def twice(self):
        with self._lock:
            with self._lock:
                pass
"""

CCY003_BAD = """\
import threading
import time

class Sleepy:
    def __init__(self):
        self._lock = threading.Lock()

    def nap(self):
        with self._lock:
            time.sleep(0.1)
"""

CCY003_QUEUE_BAD = """\
import threading

class Pump:
    def __init__(self, q):
        self._lock = threading.Lock()
        self._queue = q

    def push(self, item):
        with self._lock:
            self._queue.put(item)
"""

CCY003_WAIT_OK = """\
import threading

class Gate:
    def __init__(self):
        self._lock = threading.Lock()
        self._ready = threading.Condition(self._lock)

    def await_ready(self):
        with self._lock:
            self._ready.wait()
"""

RES001_BAD = """\
def leak(conn, payload):
    conn.send("k", payload)
"""

RES001_GOOD = """\
def roundtrip(conn, payload):
    conn.send("k", payload)
    out = conn.recv("k")
    conn.release("k")
    return out
"""

RES001_ESCAPES = """\
def handoff(conn, payload):
    conn.send("k", payload)
    schedule_cleanup("k")

def deferred(conn, key, payload):
    conn.send(key, payload)
    return lambda: conn.release(key)

def raises_path(conn):
    import pytest
    with pytest.raises(KeyError):
        conn.recv("missing")
"""

PKL001_BAD = """\
spec = EngineSpec(lambda: None)
"""

PKL001_MALFORMED = """\
spec = EngineSpec("repro.engine.stub_engine.make_stub")
"""

PKL001_PROCESS_BAD = """\
def serve(orch):
    orch.scale_up("llm", engine_factory=lambda: object(),
                  isolation="process")
"""

PKL001_RAISES_OK = """\
import pytest

def test_rejects_bad_spec():
    with pytest.raises(ValueError, match="module:callable"):
        EngineSpec("no_colon_here")
"""

DEP001_BAD = """\
def legacy(conn, x):
    conn.put("k", x)
"""

DEP002_BAD = """\
def legacy(graph):
    return Orchestrator(graph, queue_capacity=4)
"""

# (code, fixture) pairs driving the fires / noqa / baseline param tests
FIXTURES = [
    ("CCY001", CCY001_BAD),
    ("CCY001", CCY001_REQUIRES_BAD),
    ("CCY002", CCY002_BAD),
    ("CCY002", CCY002_SELF_DEADLOCK),
    ("CCY003", CCY003_BAD),
    ("CCY003", CCY003_QUEUE_BAD),
    ("RES001", RES001_BAD),
    ("PKL001", PKL001_BAD),
    ("PKL001", PKL001_MALFORMED),
    ("PKL001", PKL001_PROCESS_BAD),
    ("DEP001", DEP001_BAD),
    ("DEP002", DEP002_BAD),
]
_IDS = ["CCY001-field", "CCY001-requires", "CCY002-cycle", "CCY002-self",
        "CCY003-sleep", "CCY003-queue", "RES001-leak", "PKL001-lambda",
        "PKL001-string", "PKL001-process", "DEP001-trio", "DEP002-kwargs"]


def _codes(findings):
    return {f.code for f in findings}


def _with_noqa(src, findings, code, suppress_as=None):
    """Append a noqa marker (for ``suppress_as`` or ``code``) to every
    line the given code flagged."""
    marker = suppress_as or code
    lines = src.split("\n")
    for f in findings:
        if f.code == code:
            lines[f.line - 1] += f"  # noqa: {marker}"
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# every rule fires, and noqa / baseline suppression works for each
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("code,src", FIXTURES, ids=_IDS)
def test_rule_fires(code, src):
    findings = analyze_source(src, filename=f"fixture_{code}.py")
    assert code in _codes(findings), \
        f"{code} did not fire on its bad fixture"


@pytest.mark.parametrize("code,src", FIXTURES, ids=_IDS)
def test_noqa_with_matching_code_suppresses(code, src):
    fname = f"fixture_{code}.py"
    findings = analyze_source(src, filename=fname)
    patched = _with_noqa(src, findings, code)
    assert code not in _codes(analyze_source(patched, filename=fname))


@pytest.mark.parametrize("code,src", FIXTURES, ids=_IDS)
def test_noqa_with_other_code_does_not_suppress(code, src):
    fname = f"fixture_{code}.py"
    findings = analyze_source(src, filename=fname)
    patched = _with_noqa(src, findings, code, suppress_as="ZZZ999")
    assert code in _codes(analyze_source(patched, filename=fname))


@pytest.mark.parametrize("code,src", FIXTURES, ids=_IDS)
def test_bare_noqa_suppresses(code, src):
    fname = f"fixture_{code}.py"
    findings = analyze_source(src, filename=fname)
    lines = src.split("\n")
    for f in findings:
        if f.code == code:
            lines[f.line - 1] += "  # noqa"
    patched = "\n".join(lines)
    assert code not in _codes(analyze_source(patched, filename=fname))


@pytest.mark.parametrize("code,src", FIXTURES, ids=_IDS)
def test_baseline_absorbs_finding(code, src):
    fname = f"fixture_{code}.py"
    findings = analyze_source(src, filename=fname)
    bl = Baseline([BaselineEntry(f.file, f.code, f.source,
                                 justification="grandfathered")
                   for f in findings])
    new, old, stale = bl.split(findings)
    assert new == []
    assert len(old) == len(findings)
    assert stale == []


# ---------------------------------------------------------------------------
# rule-specific behavior beyond fires/suppresses
# ---------------------------------------------------------------------------

def test_ccy001_clean_when_locked():
    assert _codes(analyze_source(CCY001_GOOD)) == set()


def test_ccy001_flags_read_and_write():
    findings = [f for f in analyze_source(CCY001_BAD)
                if f.code == "CCY001"]
    msgs = " | ".join(f.message for f in findings)
    assert "write to 'value'" in msgs
    assert "read of 'value'" in msgs


def test_ccy001_requires_lock_call_site():
    findings = analyze_source(CCY001_REQUIRES_BAD)
    assert any("requires-lock" in f.message for f in findings)


def test_ccy002_rlock_reentry_is_fine():
    assert "CCY002" not in _codes(analyze_source(CCY002_RLOCK_OK))


def test_ccy003_condition_wait_on_held_lock_exempt():
    assert "CCY003" not in _codes(analyze_source(CCY003_WAIT_OK))


def test_res001_clean_on_release_and_escapes():
    assert "RES001" not in _codes(analyze_source(RES001_GOOD))
    assert "RES001" not in _codes(analyze_source(RES001_ESCAPES))


def test_pkl001_well_formed_string_ok():
    ok = 'spec = EngineSpec("repro.engine.stub_engine:make_stub")\n'
    assert "PKL001" not in _codes(analyze_source(ok))


def test_pkl001_pytest_raises_exempt():
    assert "PKL001" not in _codes(analyze_source(PKL001_RAISES_OK))


# ---------------------------------------------------------------------------
# framework pieces: noqa parsing, baseline multiset + trend
# ---------------------------------------------------------------------------

def test_noqa_parsing():
    assert noqa_codes("x = 1") is None
    assert noqa_codes("x = 1  # noqa") == frozenset()
    assert noqa_codes("x = 1  # noqa: DEP001") == {"DEP001"}
    assert noqa_codes("x  # noqa: CCY001, CCY003") == {"CCY001", "CCY003"}
    # trailing justification text parses; only the named code is silenced
    line = "except Exception:  # noqa: BLE001 — fault isolation"
    assert is_suppressed("BLE001", line)
    assert not is_suppressed("CCY003", line)


def test_baseline_is_multiset_aware():
    findings = analyze_source(CCY001_BAD, filename="m.py")
    one = [f for f in findings if f.code == "CCY001"][:1]
    bl = Baseline([BaselineEntry(f.file, f.code, f.source) for f in one])
    # two distinct findings, one baselined: the other must stay new
    new, old, _ = bl.split(findings)
    assert len(old) == 1 and len(new) == len(findings) - 1


def test_baseline_stale_entries_reported():
    bl = Baseline([BaselineEntry("gone.py", "CCY001", "x += 1",
                                 justification="since fixed")])
    new, old, stale = bl.split([])
    assert (new, old) == ([], []) and len(stale) == 1


def test_rebuilt_baseline_keeps_justifications():
    findings = analyze_source(RES001_BAD, filename="m.py")
    bl = Baseline([BaselineEntry(f.file, f.code, f.source,
                                 justification="keep me")
                   for f in findings])
    rebuilt = bl.rebuilt_from(findings)
    assert [e.justification for e in rebuilt.entries] == ["keep me"]


# ---------------------------------------------------------------------------
# CLI: exit codes, --json dump, --update-baseline, trend line
# ---------------------------------------------------------------------------

def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(src)
    return str(p)


def test_cli_exits_nonzero_on_new_finding(tmp_path):
    bad = _write(tmp_path, "bad.py", RES001_BAD)
    assert analyze_main([bad, "--no-baseline"]) == 1


def test_cli_exits_zero_on_clean_tree(tmp_path):
    good = _write(tmp_path, "good.py", RES001_GOOD)
    assert analyze_main([good, "--no-baseline"]) == 0


def test_cli_json_dump(tmp_path):
    bad = _write(tmp_path, "bad.py", DEP001_BAD)
    out = tmp_path / "findings.json"
    assert analyze_main([bad, "--no-baseline", "--json", str(out)]) == 1
    payload = json.loads(out.read_text())
    assert payload["counts"]["new"] == 1
    [f] = payload["findings"]
    assert f["code"] == "DEP001" and f["baselined"] is False


def test_cli_update_baseline_then_green(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", CCY003_BAD)
    bl = tmp_path / "baseline.json"
    assert analyze_main([bad, "--baseline", str(bl),
                         "--update-baseline"]) == 0
    # the grandfathered finding no longer fails the gate
    assert analyze_main([bad, "--baseline", str(bl)]) == 0
    # ...and once fixed, the stale entry surfaces as a shrink trend
    pathlib.Path(bad).write_text(CCY001_GOOD)
    assert analyze_main([bad, "--baseline", str(bl)]) == 0
    trend = [ln for ln in capsys.readouterr().out.splitlines()
             if ln.startswith("analyze trend:")]
    assert trend and "1 finding(s) fixed" in trend[0]


def test_cli_select_runs_only_named_codes(tmp_path):
    both = _write(tmp_path, "both.py", DEP001_BAD + CCY003_BAD)
    assert analyze_main([both, "--no-baseline",
                         "--select", "DEP001"]) == 1
    assert analyze_main([both, "--no-baseline",
                         "--select", "RES001"]) == 0


# ---------------------------------------------------------------------------
# self-scan: the repo itself is clean modulo the committed baseline
# ---------------------------------------------------------------------------

def test_repo_clean_modulo_committed_baseline():
    findings = analyze_paths()
    new, old, stale = Baseline.load().split(findings)
    assert new == [], "non-baselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert stale == [], "stale baseline entries (run --update-baseline):" \
        "\n" + "\n".join(f"{e.file}: {e.code} {e.source}" for e in stale)
