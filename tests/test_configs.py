"""The assigned architecture configs must match the assignment sheet."""
import pytest

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, get_config,
                                shape_skips, variant_for_shape)

EXPECTED = {
    # arch: (type, L, d_model, heads, kv, d_ff, vocab)
    "qwen2_5_14b": ("dense", 48, 5120, 40, 8, 13824, 152064),
    "internlm2_1_8b": ("dense", 24, 2048, 16, 8, 8192, 92544),
    "qwen3_moe_30b_a3b": ("moe", 48, 2048, 32, 4, 768, 151936),
    "zamba2_2_7b": ("hybrid", 54, 2560, 32, 32, 10240, 32000),
    "starcoder2_7b": ("dense", 32, 4608, 36, 4, 18432, 49152),
    "mixtral_8x7b": ("moe", 32, 4096, 32, 8, 14336, 32000),
    "qwen1_5_4b": ("dense", 40, 2560, 20, 20, 6912, 151936),
    "hubert_xlarge": ("audio", 48, 1280, 16, 16, 5120, 504),
    "falcon_mamba_7b": ("ssm", 64, 4096, 0, 0, 0, 65024),
    "chameleon_34b": ("vlm", 48, 8192, 64, 8, 22016, 65536),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assignment(arch):
    t, L, d, h, kv, ff, v = EXPECTED[arch]
    cfg = get_config(arch)
    assert cfg.arch_type == t
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, "config must cite its source"


def test_special_features():
    assert get_config("qwen2_5_14b").qkv_bias
    assert get_config("qwen1_5_4b").qkv_bias
    q3 = get_config("qwen3_moe_30b_a3b")
    assert (q3.num_experts, q3.experts_per_token) == (128, 8)
    mx = get_config("mixtral_8x7b")
    assert (mx.num_experts, mx.experts_per_token) == (8, 2)
    assert mx.attn_variant == "swa" and mx.sliding_window == 4096
    zb = get_config("zamba2_2_7b")
    assert zb.ssm_state == 64 and zb.ssm_version == 2
    assert zb.shared_attn_every > 0
    fm = get_config("falcon_mamba_7b")
    assert fm.ssm_state == 16 and fm.ssm_version == 1
    assert get_config("hubert_xlarge").is_encoder
    assert get_config("chameleon_34b").modality == "vq_image+text"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4


def test_param_counts_plausible():
    # analytic param counts should land near the advertised sizes
    approx = {
        "qwen2_5_14b": 14e9, "internlm2_1_8b": 1.8e9,
        "qwen3_moe_30b_a3b": 30e9, "zamba2_2_7b": 2.7e9,
        "starcoder2_7b": 7e9, "mixtral_8x7b": 47e9, "qwen1_5_4b": 4e9,
        "hubert_xlarge": 1e9, "falcon_mamba_7b": 7e9, "chameleon_34b": 34e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.8 * target, (arch, n, target)


def test_shape_skips():
    hub = get_config("hubert_xlarge")
    assert shape_skips(hub, INPUT_SHAPES["decode_32k"])
    assert shape_skips(hub, INPUT_SHAPES["long_500k"])
    assert not shape_skips(hub, INPUT_SHAPES["train_4k"])
    dense = get_config("qwen2_5_14b")
    assert not shape_skips(dense, INPUT_SHAPES["long_500k"])
    v = variant_for_shape(dense, INPUT_SHAPES["long_500k"])
    assert v.attn_variant == "swa" and v.sliding_window > 0
    fm = variant_for_shape(get_config("falcon_mamba_7b"),
                           INPUT_SHAPES["long_500k"])
    assert fm.attn_variant == "full"   # SSM needs no windowing
