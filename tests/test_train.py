"""Training substrate: loss decreases, checkpoint round-trips, data shapes."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.configs.pipelines import tiny_lm
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.data import TokenStream
from repro.train.optimizer import AdamWConfig, init_opt_state, lr_at
from repro.train.step import make_train_step


def test_loss_decreases():
    cfg = tiny_lm("train_t", vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)))
    ds = iter(TokenStream(cfg, batch=8, seq_len=32, seed=0))
    losses = []
    for i in range(30):
        b = next(ds)
        params, opt, m = step(params, opt, jnp.asarray(b["inputs"]),
                              jnp.asarray(b["labels"]))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses).all()


def test_moe_train_has_aux():
    cfg = get_config("qwen3_moe_30b_a3b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    ds = iter(TokenStream(cfg, batch=2, seq_len=16))
    b = next(ds)
    _, _, m = step(params, opt, jnp.asarray(b["inputs"]),
                   jnp.asarray(b["labels"]))
    assert float(m["aux"]) > 0


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, jnp.asarray(5))) < 1e-3
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-6
    assert float(lr_at(cfg, jnp.asarray(100))) < 1e-5


def test_checkpoint_roundtrip_bf16(tmp_path):
    """bfloat16 params survive the npz round trip (void-dtype view)."""
    cfg = tiny_lm("ckpt_bf", vocab=64).replace(dtype="bfloat16")
    params = T.init_params(cfg, jax.random.PRNGKey(2))
    path = os.path.join(tmp_path, "bf.npz")
    checkpoint.save(path, params, step=3)
    p2, _, step = checkpoint.load(path, params)
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_lm("ckpt_t", vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, params, opt, step=17)
    p2, o2, step = checkpoint.load(path, params, opt)
    assert step == 17
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_modalities():
    text = get_config("qwen2_5_14b", smoke=True)
    b = next(iter(TokenStream(text, 4, 32)))
    assert b["inputs"].shape == (4, 32) and b["labels"].shape == (4, 32)
    assert b["inputs"].max() < text.vocab_size
    audio = get_config("hubert_xlarge", smoke=True)
    b = next(iter(TokenStream(audio, 2, 16)))
    assert b["inputs"].shape == (2, 16, audio.d_model)
    vlm = get_config("chameleon_34b", smoke=True)
    b = next(iter(TokenStream(vlm, 2, 32)))
    assert (b["inputs"] >= vlm.vocab_size // 2).any(), "has image tokens"


def test_data_deterministic():
    cfg = tiny_lm("det", vocab=64)
    a = next(iter(TokenStream(cfg, 2, 16, seed=5)))
    b = next(iter(TokenStream(cfg, 2, 16, seed=5)))
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
