"""Preemption (recompute mode): under page pressure the engine evicts the
youngest running request and re-prefills it later — greedy output must
STILL exactly match the unpressured reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pipelines import tiny_lm
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.engine.scheduler import Scheduler
from repro.models import transformer as T


def _greedy_reference(cfg, params, prompt, n_new, max_seq=256):
    toks = jnp.asarray(prompt)[None]
    logits, cache = T.forward_prefill(cfg, params, toks, max_seq,
                                      remat=False)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        t = jnp.array([[out[-1]]], jnp.int32)
        logits, cache = T.forward_decode(cfg, params, cache, t,
                                         jnp.array([pos]))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def test_scheduler_preempts_under_pressure():
    kv = PagedKVConfig(num_pages=10, page_size=8, max_pages_per_seq=10)
    sched = Scheduler(kv, max_batch=4, enable_preemption=True)
    # both prompts fit exactly (5 pages each); decode growth will OOM
    sched.add(0, 40, SamplingParams(max_new_tokens=8))
    sched.add(1, 40, SamplingParams(max_new_tokens=8))
    plan = sched.schedule()
    assert plan.admitted == [0, 1]
    for rid in (0, 1):
        sched.note_prefill(rid, 40)
        sched.note_sampled(rid, 5)
    # next decode writes at pos 40 -> both need a 6th page; pool empty ->
    # the YOUNGEST (1) is preempted so the oldest (0) keeps decoding
    plan = sched.schedule()
    assert plan.preempted == [1]
    assert plan.decode_req_ids == [0]
    assert sched.preemptions == 1
    assert sched.allocator.check_invariant()
    assert sched.waiting[0].req_id == 1
    assert sched.waiting[0].resumed
    # re-prefill prompt now includes the already-sampled token's history
    assert sched.waiting[0].prompt_len == 40  # generated=1 -> +0


def test_preempted_request_output_unchanged():
    cfg = tiny_lm("pre", vocab=256)
    params = T.init_params(cfg, jax.random.PRNGKey(5))
    rng = np.random.default_rng(1)
    # pool fits 2 prompts but not their decode growth -> guaranteed churn
    kv = PagedKVConfig(num_pages=12, page_size=8, max_pages_per_seq=12)
    n_new = 16
    eng = AREngine("pre", cfg, params, kv=kv, max_batch=3,
                   default_sampling=SamplingParams(max_new_tokens=n_new,
                                                   temperature=0.0))
    eng.scheduler.enable_preemption = True
    prompts = [rng.integers(0, 256, size=40).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
    results = {}
    for _ in range(2000):
        for ev in eng.step():
            if ev.kind == "finished":
                results[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    assert len(results) == 3
    assert eng.scheduler.preemptions >= 1, "test must exercise preemption"
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, params, p, n_new)
        assert results[i] == want, (i, results[i], want)
