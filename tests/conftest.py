"""Tests run on the single real CPU device (the 512-device forcing is
confined to repro.launch.dryrun, which tests never import)."""
import os
import sys
import types

# make sure nothing leaked the dry-run device forcing into the test env
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "host_platform_device_count" not in f)

import jax
import pytest

# ---------------------------------------------------------------------------
# hypothesis shim: offline environments don't have hypothesis installed, and
# 5 test modules import it at collection time.  When it's missing we install
# a stub into sys.modules whose @given replaces each property test with a
# zero-argument skipper, so the rest of each module still collects and runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            def _skipper():
                pytest.skip("hypothesis not installed (offline environment)")
            _skipper.__name__ = fn.__name__
            _skipper.__doc__ = fn.__doc__
            return _skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """Stands in for strategy builders: any attribute access or call
        (st.integers(1, 8), hnp.arrays(...)) yields another stub."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _AnyStrategy()
    _extra = types.ModuleType("hypothesis.extra")
    _hnp = types.ModuleType("hypothesis.extra.numpy")
    _hnp.__getattr__ = lambda name: _AnyStrategy()
    _hyp.strategies = _st
    _hyp.extra = _extra
    _extra.numpy = _hnp
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    sys.modules["hypothesis.extra"] = _extra
    sys.modules["hypothesis.extra.numpy"] = _hnp


# ---------------------------------------------------------------------------
# fast tier: `pytest -m fast` runs a sub-minute smoke subset (the default
# pre-commit check, see Makefile).  Membership is by module: these modules
# use stub engines / pure-python structures, not jitted model forwards.
# ---------------------------------------------------------------------------
_FAST_MODULES = {
    "test_configs", "test_stage_graph", "test_connector", "test_sharding",
    "test_scheduler", "test_worker_backend", "test_kv_prefix_cache",
    "test_replicas", "test_radix_index", "test_serve_config",
    "test_process_worker", "test_analyzer",
}


def pytest_collection_modifyitems(items):
    for item in items:
        if item.module.__name__ in _FAST_MODULES:
            item.add_marker(pytest.mark.fast)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
