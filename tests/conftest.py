"""Tests run on the single real CPU device (the 512-device forcing is
confined to repro.launch.dryrun, which tests never import)."""
import os

# make sure nothing leaked the dry-run device forcing into the test env
flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" in flags:
    os.environ["XLA_FLAGS"] = " ".join(
        f for f in flags.split() if "host_platform_device_count" not in f)

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
