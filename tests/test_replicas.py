"""Multi-replica stage serving: routing policies, scale_up/scale_down
lifecycle, per-replica metrics, and the metrics-driven ScalingController.

Uses pure-python stub engines (no jax) so this module is in the fast tier.
"""
import time

import pytest

from repro.core.config import ServeConfig, StageConfig
from repro.core.graph import StageGraph
from repro.core.metrics import stage_report
from repro.core.orchestrator import (CacheAffinityPolicy, Orchestrator,
                                     make_routing_policy)
from repro.core.request import Request, StageEvent
from repro.core.scaling import ScalingConfig, ScalingController
from repro.core.stage import StageSpec
from repro.core.worker import StageInput


class StubEngine:
    """One finished event per queued item, optional per-step dwell."""

    def __init__(self, name, delay=0.0):
        self.name = name
        self.delay = delay
        self.q = []
        self.busy_time = 0.0

    def enqueue(self, req_id, inputs, sampling, data):
        self.q.append((req_id, dict(inputs)))

    @property
    def has_work(self):
        return bool(self.q)

    @property
    def queue_depth(self):
        return len(self.q)

    def step(self):
        if not self.q:
            return []
        if self.delay:
            time.sleep(self.delay)
        self.busy_time += self.delay
        rid, inp = self.q.pop(0)
        return [StageEvent(rid, "finished", {"x": inp.get("x", 0) + 1},
                           stage=self.name)]


def _single_stage(n_replicas, delay=0.0, routing="least_loaded",
                  factory=False):
    graph = StageGraph()
    graph.add_stage(StageSpec("s", "custom", is_output=True))
    engines = {"s": [StubEngine("s", delay) for _ in range(n_replicas)]}
    stages = ({"s": StageConfig(engine_factory=lambda: StubEngine("s", delay))}
              if factory else {})
    return Orchestrator(graph, engines,
                        config=ServeConfig(routing=routing, stages=stages))


def _serve(orch, n):
    reqs = [Request(inputs={"x": 0}) for _ in range(n)]
    for r in reqs:
        orch.submit(r)
    return reqs


# ---------------------------------------------------------------------------
# routing policies (pure, deterministic)
# ---------------------------------------------------------------------------

class _FakeEngine:
    def __init__(self, resident_blocks):
        self.resident_blocks = resident_blocks

    def prefix_hint(self, hints):
        return min(self.resident_blocks, len(hints))


class _FakeWorker:
    def __init__(self, resident_blocks=0, load=0):
        self.engine = _FakeEngine(resident_blocks)
        self._load = load

    def load(self):
        return self._load


def _item(hints=None, inputs=None):
    return StageInput(Request(inputs=inputs or {}), None, inputs=inputs,
                      affinity_hints=hints)


HINTS = [("tok", b"a"), ("tok", b"b"), ("tok", b"c")]


def test_affinity_deterministic_given_fixed_hints():
    pol = make_routing_policy("affinity")
    assert isinstance(pol, CacheAffinityPolicy)
    # longest prefix match wins even over an idle zero-hint replica
    replicas = [(0, _FakeWorker(resident_blocks=0, load=0)),
                (1, _FakeWorker(resident_blocks=2, load=5)),
                (2, _FakeWorker(resident_blocks=1, load=0))]
    for _ in range(10):
        assert pol.select("s", replicas, _item(hints=HINTS)) == 1
    # ties on the hint break by load, then lowest replica id
    tied = [(0, _FakeWorker(2, load=3)), (1, _FakeWorker(2, load=0)),
            (2, _FakeWorker(2, load=0))]
    for _ in range(10):
        assert pol.select("s", tied, _item(hints=HINTS)) == 1


def test_affinity_falls_back_to_least_loaded():
    pol = make_routing_policy("affinity")
    replicas = [(0, _FakeWorker(0, load=4)), (1, _FakeWorker(0, load=1))]
    # zero hint everywhere -> least loaded
    assert pol.select("s", replicas, _item(hints=HINTS)) == 1
    # no hints computable: probed once, cached as [] on the item
    item = _item(inputs={"x": 1})
    assert pol.select("s", replicas, item) == 1
    assert item.affinity_hints == []


def test_round_robin_cycles_per_stage():
    pol = make_routing_policy("round_robin")
    replicas = [(0, _FakeWorker()), (1, _FakeWorker()), (2, _FakeWorker())]
    picks = [pol.select("s", replicas, _item()) for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    assert pol.select("other", replicas, _item()) == 0   # per-stage cursor


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_routing_policy("hash_ring")


# ---------------------------------------------------------------------------
# ReplicaSet serving + scaling lifecycle
# ---------------------------------------------------------------------------

def test_replicas_serve_and_report_per_replica_metrics():
    orch = _single_stage(2, delay=0.004)
    orch.start()
    reqs = _serve(orch, 10)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(r.completion_time is not None and not r.failed for r in reqs)
    sm = orch.stage_metrics()
    assert sm["s"]["admitted"] == 10 and sm["s"]["n_replicas"] == 2
    reps = sm["s"]["replicas"]
    assert set(reps) == {0, 1}
    assert sum(r["admitted"] for r in reps.values()) == 10
    # least-loaded under a 4ms dwell spreads work across both replicas
    assert all(r["admitted"] > 0 for r in reps.values())
    report = stage_report(sm)
    assert "s/0" in report and "s/1" in report
    # replica_failures column only appears once a replica actually died
    assert "replica_failures" not in report
    sm["s"]["replica_failures"] = 1
    assert "replica_failures" in stage_report(sm)


def test_scale_down_drain_loses_no_requests():
    orch = _single_stage(3, delay=0.005)
    orch.start()
    reqs = _serve(orch, 24)                  # queued across all 3 replicas
    retired = orch.scale_down("s", drain=True)
    assert retired is True
    assert orch.replica_counts() == {"s": 2}
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(r.completion_time is not None and not r.failed for r in reqs)
    assert orch.stage_metrics()["s"]["finished"] == 24


def test_retired_replica_never_routed():
    orch = _single_stage(2, delay=0.002, routing="least_loaded")
    orch.start()
    _serve(orch, 4)
    rs = orch._workers["s"]
    rid = rs.scale_down(drain=True)
    assert rid is not None
    admitted_at_retire = orch._stage_metrics["s"][rid].admitted
    reqs = _serve(orch, 12)                  # all must land on the survivor
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(not r.failed for r in reqs)
    assert orch._stage_metrics["s"][rid].admitted == admitted_at_retire
    assert rid not in rs.replica_ids


def test_scale_floor_is_one_replica():
    orch = _single_stage(1)
    orch.start()
    assert orch.scale_down("s") is False
    orch.shutdown()


def test_scale_up_at_runtime_and_rid_reuse():
    orch = _single_stage(2, delay=0.002, factory=True)
    orch.start()
    rs = orch._workers["s"]
    retired = rs.scale_down(drain=True)
    assert rs.scale_up() == retired          # smallest free id is reused
    assert orch.replica_counts() == {"s": 2}
    reqs = _serve(orch, 8)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(not r.failed for r in reqs)
    # restart keeps the scaled topology (engines synced at shutdown)
    assert len(orch.stage_replicas["s"]) == 2


def test_replica_spec_without_factory_rejected():
    graph = StageGraph()
    graph.add_stage(StageSpec("s", "custom", is_output=True))
    with pytest.raises(ValueError, match="factory"):
        Orchestrator(graph, {"s": StubEngine("s")},
                     config=ServeConfig(stages={"s": StageConfig(replicas=3)}))


def test_sync_backend_rejects_multi_replica():
    graph = StageGraph()
    graph.add_stage(StageSpec("s", "custom", is_output=True))
    with pytest.raises(ValueError, match="single-replica"):
        Orchestrator(graph, {"s": [StubEngine("s"), StubEngine("s")]},
                     backend="sync")


# ---------------------------------------------------------------------------
# warm-seeded scale_up + sticky chunk-stream routing
# ---------------------------------------------------------------------------

class SeedableEngine(StubEngine):
    """Stub exposing the engine-side warm-seed protocol surface
    (cached_prefix_pages / prefix_snapshot / seed_prefixes / prefix_hint)."""

    def __init__(self, name, pages=0, delay=0.0):
        super().__init__(name, delay)
        self.cached_prefix_pages = pages
        self.seeded = None

    def prefix_snapshot(self, max_pages=64):
        return [{"pages": self.cached_prefix_pages}]

    def seed_prefixes(self, snapshot):
        self.seeded = snapshot
        n = sum(e["pages"] for e in snapshot)
        self.cached_prefix_pages += n
        return n

    def prefix_hint(self, hints):
        return self.cached_prefix_pages


def test_scale_up_warm_seeds_from_warmest_sibling():
    from repro.core.worker import ReplicaSet
    engines = [SeedableEngine("s", pages=5), SeedableEngine("s", pages=2)]
    rs = ReplicaSet("s", engines, lambda st, ev: None,
                    engine_factory=lambda: SeedableEngine("s"))
    rid = rs.scale_up()
    assert rid == 2
    new = rs._replicas[rid].engine
    # seeded from the 5-page sibling (the warmest), not the 2-page one
    assert new.cached_prefix_pages == 5
    assert new.seeded == [{"pages": 5}]
    # no seed_connector on this set: the direct hand-off path is audited
    assert rs.seed_events == [{"rid": 2, "donor_pages": 5, "pages": 5,
                               "via": "direct"}]


def test_scale_up_cold_without_snapshot_support_or_when_disabled():
    from repro.core.worker import ReplicaSet
    # siblings without the snapshot surface: cold start, no event
    rs = ReplicaSet("s", [StubEngine("s")], lambda st, ev: None,
                    engine_factory=lambda: SeedableEngine("s"))
    assert rs.scale_up() == 1
    assert rs.seed_events == []
    # warm_seed=False: seeding is off even with a warm donor
    rs2 = ReplicaSet("s", [SeedableEngine("s", pages=4)],
                     lambda st, ev: None,
                     engine_factory=lambda: SeedableEngine("s"),
                     warm_seed=False)
    assert rs2.scale_up() == 1
    assert rs2._replicas[1].engine.cached_prefix_pages == 0
    assert rs2.seed_events == []


def test_orchestrator_scale_up_warm_seeds():
    graph = StageGraph()
    graph.add_stage(StageSpec("s", "custom", is_output=True))
    orch = Orchestrator(graph, {"s": [SeedableEngine("s", pages=3)]},
                        config=ServeConfig(stages={"s": StageConfig(
                            engine_factory=lambda: SeedableEngine("s"))}))
    orch.start()
    assert orch.scale_up("s")
    rs = orch._workers["s"]
    assert rs.seed_events and rs.seed_events[-1]["pages"] == 3
    orch.shutdown()


def test_seq_items_stick_to_one_replica():
    from repro.core.worker import ReplicaSet
    rs = ReplicaSet("s", [StubEngine("s"), StubEngine("s")],
                    lambda st, ev: None)
    req = Request(inputs={})
    for i in range(4):
        assert rs.submit(StageInput(req, None, inputs={"x": i}, seq=i))
    depths = sorted(rs._replicas[r].inbox.qsize() for r in rs.replica_ids)
    assert depths == [0, 4], "a chunk stream must stay on one replica"
    # unordered items from another request still spread round-robin
    other = Request(inputs={})
    for i in range(2):
        assert rs.submit(StageInput(other, None, inputs={"x": i}))
    assert other.req_id not in rs._sticky
    rs.forget(req.req_id)
    assert req.req_id not in rs._sticky


# ---------------------------------------------------------------------------
# connector accounting with replicas
# ---------------------------------------------------------------------------

def test_connector_resident_bytes_balanced_across_replicas():
    import numpy as np

    class BlobEngine(StubEngine):
        def step(self):                      # payload with real bytes, so
            evs = super().step()             # the shm pool holds something
            for ev in evs:
                ev.payload["blob"] = np.zeros(256, np.float32)
            return evs

    graph = StageGraph()
    graph.add_stage(StageSpec("a", "custom"))
    graph.add_stage(StageSpec("b", "custom", is_output=True))
    graph.add_edge("a", "b", lambda d, p: {"x": p["x"]}, connector="shm")
    engines = {"a": BlobEngine("a"),
               "b": [StubEngine("b", 0.002) for _ in range(3)]}
    orch = Orchestrator(graph, engines,
                        config=ServeConfig(routing="least_loaded"))
    reqs = _serve(orch, 12)
    orch.run(timeout=60.0)
    assert all(r.completion_time is not None and not r.failed for r in reqs)
    conn = orch.connectors["shm"]
    # every transfer was received+released by exactly one replica worker:
    # lifetimes balance even though three threads consume the channel
    assert conn.stats.calls == 12
    assert conn.peak_resident_bytes > 0
    assert conn.resident_bytes == 0


# ---------------------------------------------------------------------------
# metrics-driven scaling controller
# ---------------------------------------------------------------------------

def test_autoscale_moves_replica_to_bottleneck():
    graph = StageGraph()
    graph.add_stage(StageSpec("pre", "custom"))
    graph.add_stage(StageSpec("gen", "custom", is_output=True))
    graph.add_edge("pre", "gen", lambda d, p: {"x": p["x"]})
    engines = {"pre": [StubEngine("pre", 0.001) for _ in range(2)],
               "gen": [StubEngine("gen", 0.02) for _ in range(2)]}
    def _pre():
        return StubEngine("pre", 0.001)

    def _gen():
        return StubEngine("gen", 0.02)

    orch = Orchestrator(graph, engines, config=ServeConfig(
        routing="least_loaded",
        stages={"pre": StageConfig(engine_factory=_pre),
                "gen": StageConfig(engine_factory=_gen)}))
    ctl = ScalingController(orch, ScalingConfig(
        interval=0.1, cooldown=0, replica_budget=4))
    orch.start()
    reqs = _serve(orch, 30)
    ctl.tick()                               # baseline measurement window
    action = None
    for _ in range(30):                      # gen saturates within ~100ms
        time.sleep(0.1)
        action = ctl.tick()
        if action:
            break
    assert action is not None, "controller never acted on the bottleneck"
    assert action["kind"] == "move" and action["stage"] == "gen"
    assert action["donor"] == "pre"
    assert orch.replica_counts() == {"pre": 1, "gen": 3}
    assert ctl.actions and ctl.actions[-1]["replicas"]["gen"] == 3
    assert orch.drain(timeout=60.0)
    orch.shutdown()
    assert all(r.completion_time is not None and not r.failed for r in reqs)
    assert orch.stage_metrics()["gen"]["finished"] == 30


def test_autoscale_add_uses_budget_headroom():
    orch = _single_stage(1, delay=0.02, factory=True)
    ctl = ScalingController(orch, ScalingConfig(
        interval=0.1, cooldown=0, replica_budget=2))
    orch.start()
    reqs = _serve(orch, 20)
    ctl.tick()
    action = None
    for _ in range(30):
        time.sleep(0.1)
        action = ctl.tick()
        if action:
            break
    assert action is not None and action["kind"] == "add"
    assert orch.replica_counts() == {"s": 2}
    assert orch.drain(timeout=60.0)
    orch.shutdown()
    assert all(not r.failed for r in reqs)


def test_autoscale_respects_budget_and_factory_gate():
    # no factory: the controller must never act, however hot the stage is
    orch = _single_stage(1, delay=0.02, factory=False)
    ctl = ScalingController(orch, ScalingConfig(
        interval=0.1, cooldown=0, replica_budget=4))
    orch.start()
    reqs = _serve(orch, 10)
    ctl.tick()
    time.sleep(0.15)
    assert ctl.tick() is None
    assert orch.replica_counts() == {"s": 1}
    assert orch.drain(timeout=60.0)
    orch.shutdown()
    assert all(not r.failed for r in reqs)


def test_replica_failure_then_scale_down():
    """Regression: scale_down used to re-read _replicas[rid] after
    dropping the lock, racing _on_replica_failure's delete.  A retired-
    by-failure replica must not break a subsequent scale_down, and the
    failure event must land in the locked trace."""
    orch = _single_stage(3, delay=0.002)
    orch.start()
    rs = orch._workers["s"]
    rid = rs.replica_ids[0]
    w = rs._replicas[rid]
    rs._on_replica_failure(w, [])            # simulate the pump's callback
    w.stop(drain=False)
    w.join(timeout=10.0)
    assert rid not in rs.replica_ids
    assert [e["rid"] for e in rs.failure_events] == [rid]
    retired = rs.scale_down(drain=True)      # must not KeyError
    assert retired is not None and retired != rid
    assert orch.replica_counts() == {"s": 1}
    reqs = _serve(orch, 6)
    assert orch.drain(timeout=30.0)
    orch.shutdown()
    assert all(not r.failed for r in reqs)


def test_scaling_action_log_is_a_safe_copy():
    """Regression: benchmarks read the decision trace while the
    controller thread appends; action_log() hands out a copy taken
    under the controller's lock."""
    orch = _single_stage(1)
    ctl = ScalingController(orch)
    assert ctl.action_log() == []
    assert ctl.action_log() is not ctl.actions
    with ctl._lock:
        pass                                 # the lock exists and is free
