"""AR engine integration tests.

The crucial one: the paged-KV engine with greedy sampling must generate
EXACTLY the tokens a naive dense-cache decode loop produces with the same
weights — validating chunked prefill + paged attention end-to-end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.pipelines import tiny_lm
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def _greedy_reference(cfg, params, prompt, n_new, max_seq=256):
    toks = jnp.asarray(prompt)[None]
    logits, cache = T.forward_prefill(cfg, params, toks, max_seq,
                                      remat=False)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        t = jnp.array([[out[-1]]], jnp.int32)
        logits, cache = T.forward_decode(cfg, params, cache, t,
                                         jnp.array([pos]))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


def _engine(cfg, params, **kw):
    kv = PagedKVConfig(num_pages=64, page_size=8, max_pages_per_seq=16)
    defaults = dict(kv=kv, max_batch=4, token_budget=64, chunk_size=16)
    defaults.update(kw)
    return AREngine("eng", cfg, params, **defaults)


@pytest.fixture(scope="module")
def lm():
    cfg = tiny_lm("t", vocab=256)
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    return cfg, params


def test_paged_engine_matches_dense_greedy(lm):
    cfg, params = lm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 256, size=n).astype(np.int32)
               for n in (5, 23, 17, 40)]   # exercise multi-chunk prefill
    n_new = 8
    eng = _engine(cfg, params,
                  default_sampling=SamplingParams(max_new_tokens=n_new,
                                                  temperature=0.0))
    for i, p in enumerate(prompts):
        eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
    results = {}
    for _ in range(500):
        for ev in eng.step():
            if ev.kind == "finished":
                results[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    assert len(results) == len(prompts)
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, params, p, n_new)
        assert results[i] == want, f"req {i}: {results[i]} != {want}"


def test_engine_streams_chunks(lm):
    cfg, params = lm
    eng = _engine(cfg, params, stream_chunk=4,
                  default_sampling=SamplingParams(max_new_tokens=10,
                                                  temperature=0.0))
    eng.enqueue(0, {"tokens": np.arange(6, dtype=np.int32)},
                SamplingParams(), {})
    chunks, fin = [], []
    for _ in range(200):
        for ev in eng.step():
            (chunks if ev.kind == "chunk" else fin).append(ev)
        if not eng.has_work:
            break
    assert len(fin) == 1
    total = np.concatenate([c.payload["tokens"] for c in chunks])
    np.testing.assert_array_equal(total, fin[0].payload["tokens"])
    assert chunks[-1].is_last
    assert [c.chunk_index for c in chunks] == list(range(len(chunks)))


def test_engine_hidden_collection(lm):
    cfg, params = lm
    eng = _engine(cfg, params, collect_hidden=True,
                  default_sampling=SamplingParams(max_new_tokens=5,
                                                  temperature=0.0))
    eng.enqueue(0, {"tokens": np.arange(4, dtype=np.int32)},
                SamplingParams(), {})
    fin = None
    for _ in range(100):
        for ev in eng.step():
            if ev.kind == "finished":
                fin = ev
        if not eng.has_work:
            break
    assert fin is not None
    assert fin.payload["hidden"].shape == (5, cfg.d_model)
    assert np.isfinite(fin.payload["hidden"]).all()


def test_engine_prompt_embeds_and_preprocess(lm):
    cfg, params = lm
    extra = np.zeros((cfg.d_model,), np.float32)
    calls = []

    def prep(data, state):
        calls.append(state["phase"])
        return {"extra_embed": extra}

    eng = _engine(cfg, params, preprocess=prep,
                  default_sampling=SamplingParams(max_new_tokens=4,
                                                  temperature=0.0))
    pe = np.asarray(params["embed"][jnp.arange(5)])
    eng.enqueue(0, {"prompt_embeds": pe}, SamplingParams(), {})
    for _ in range(100):
        eng.step()
        if not eng.has_work:
            break
    assert "prefill" in calls and "decode" in calls


def test_ssm_engine_generates():
    cfg = get_config("falcon_mamba_7b", smoke=True).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(4))
    eng = _engine(cfg, params,
                  default_sampling=SamplingParams(max_new_tokens=6,
                                                  temperature=0.0))
    eng.enqueue(0, {"tokens": np.arange(8, dtype=np.int32)},
                SamplingParams(), {})
    fin = None
    for _ in range(100):
        for ev in eng.step():
            if ev.kind == "finished":
                fin = ev
        if not eng.has_work:
            break
    want = _greedy_reference(cfg, params, np.arange(8, dtype=np.int32), 6)
    assert list(fin.payload["tokens"]) == want


def test_int8_paged_engine_matches_transformer_int8(lm):
    """The int8 paged serving engine must produce exactly the tokens of an
    int8 dense-cache greedy loop (same per-(token,head) quantization)."""
    cfg, params = lm
    cfgq = cfg.replace(kv_cache_dtype="int8")
    prompt = np.arange(11, dtype=np.int32)
    n_new = 6
    # reference: transformer-path int8 dense cache greedy
    toks = jnp.asarray(prompt)[None]
    logits, cache = T.forward_prefill(cfgq, params, toks, 64, remat=False)
    want = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        t = jnp.array([[want[-1]]], jnp.int32)
        logits, cache = T.forward_decode(cfgq, params, cache, t,
                                         jnp.array([pos]))
        want.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    # engine: int8 paged pool
    eng = _engine(cfgq, params,
                  default_sampling=SamplingParams(max_new_tokens=n_new,
                                                  temperature=0.0))
    assert eng.runner.k_pages.dtype == jnp.int8
    eng.enqueue(0, {"tokens": prompt}, SamplingParams(), {})
    got = None
    for _ in range(200):
        for ev in eng.step():
            if ev.kind == "finished":
                got = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    assert got == want, (got, want)


def test_eos_stops_generation(lm):
    cfg, params = lm
    # find the greedy first token, then use it as EOS
    first = _greedy_reference(cfg, params, np.arange(5, dtype=np.int32), 1)[0]
    eng = _engine(cfg, params,
                  default_sampling=SamplingParams(max_new_tokens=50,
                                                  temperature=0.0,
                                                  eos_token=first))
    eng.enqueue(0, {"tokens": np.arange(5, dtype=np.int32)},
                SamplingParams(), {})
    fin = None
    for _ in range(200):
        for ev in eng.step():
            if ev.kind == "finished":
                fin = ev
        if not eng.has_work:
            break
    assert len(fin.payload["tokens"]) == 1
