"""End-to-end behaviour tests for the full disaggregated serving system."""
import jax
import numpy as np

from repro.baselines.monolithic import MonolithicQwenOmni
from repro.configs.pipelines import build_qwen_omni
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.launch.serve import build_single_arch
from repro.models.dit import DiTConfig, init_dit


def _run(graph, engines, reqs):
    orch = Orchestrator(graph, engines)
    for r in reqs:
        orch.submit(r)
    return orch, orch.run()


def test_single_arch_serving_all_families():
    """The serve launcher must serve dense, MoE and SSM archs alike."""
    rng = np.random.default_rng(0)
    for arch in ("internlm2_1_8b", "mixtral_8x7b", "falcon_mamba_7b"):
        graph, engines, _ = build_single_arch(arch, max_batch=2, max_new=4)
        reqs = [Request(inputs={"tokens": rng.integers(
            0, 500, size=6).astype(np.int32)}) for _ in range(3)]
        _, done = _run(graph, engines, reqs)
        assert len(done) == 3, arch
        for r in done:
            toks = r.outputs[arch][0]["tokens"]
            assert len(toks) == 4, arch


def test_qwen3_style_cnn_vocoder_pipeline():
    graph, engines, _ = build_qwen_omni(
        max_batch=2, thinker_tokens=4, talker_tokens=12, stream_chunk=4,
        vocoder_kind="cnn")
    reqs = [Request(inputs={"tokens": np.arange(8, dtype=np.int32)})]
    _, done = _run(graph, engines, reqs)
    assert len(done) == 1
    chunks = done[0].outputs["vocoder"]
    total = sum(c["latent"].shape[0] for c in chunks)
    assert total == 12 * 2          # CNN vocoder upsamples 2x


def test_request_data_dict_flows_through_stages():
    """The per-request data dict (paper §3.3) must accumulate intermediate
    tensors visible to downstream transfer/preprocess functions."""
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=4,
                                        talker_tokens=8, dit_steps=2)
    req = Request(inputs={"tokens": np.arange(6, dtype=np.int32)})
    _, done = _run(graph, engines, [req])
    assert "thinker_hidden" in req.data
    assert "thinker_tokens" in req.data
    assert req.data["thinker_hidden"].shape[0] == 4


def test_monolithic_baseline_runs():
    graph, engines, bundle = build_qwen_omni(max_batch=2, thinker_tokens=4,
                                             talker_tokens=8, dit_steps=2)
    vcfg = DiTConfig(name="v", num_layers=2, d_model=128, num_heads=4,
                     d_ff=256, in_dim=32, cond_dim=128, num_steps=2)
    mono = MonolithicQwenOmni(bundle, (vcfg, init_dit(vcfg,
                                                      jax.random.PRNGKey(0))),
                              dit_steps=2)
    res = mono.run([np.arange(6, dtype=np.int32)])
    assert len(res) == 1
    assert res[0]["text"].shape == (4,)
    assert res[0]["codec"].shape == (8,)
    assert res[0]["wave"].shape[1] == 16   # 8 codec tokens * 2 frames
    assert np.isfinite(res[0]["wave"]).all()


def test_jct_monotone_with_queueing():
    """Later-submitted identical requests cannot finish before earlier ones
    under FIFO admission with a saturated single-slot engine."""
    graph, engines, _ = build_qwen_omni(max_batch=1, thinker_tokens=3,
                                        talker_tokens=4, dit_steps=2)
    reqs = [Request(inputs={"tokens": np.arange(6, dtype=np.int32)})
            for _ in range(3)]
    _, done = _run(graph, engines, reqs)
    assert len(done) == 3
    finish = {r.req_id: r.completion_time for r in done}
    ids = [r.req_id for r in reqs]
    assert finish[ids[0]] <= finish[ids[1]] <= finish[ids[2]]


def test_int8_kv_cache_end_to_end():
    """Quantized-KV decode must stay close to full-precision decode."""
    import jax.numpy as jnp
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("internlm2_1_8b", smoke=True).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0,
                              cfg.vocab_size)
    full, _ = T.forward_full(cfg, params, toks, remat=False)
    cfgq = cfg.replace(kv_cache_dtype="int8")
    lo, cache = T.forward_prefill(cfgq, params, toks[:, :8], max_seq=16,
                                  remat=False)
    assert cache["k"].dtype == jnp.int8
    lo, cache = T.forward_decode(cfgq, params, cache, toks[:, 8:9],
                                 jnp.array([8]))
    rel = float(jnp.max(jnp.abs(lo[:, 0] - full[:, 8]))
                / jnp.max(jnp.abs(full[:, 8])))
    assert rel < 0.05, rel
