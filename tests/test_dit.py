"""DiT model + diffusion engine tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.diffusion_engine import DiffusionEngine
from repro.models.dit import DiTConfig, dit_forward, init_dit, sample


CFG = DiTConfig(num_layers=2, d_model=64, num_heads=2, d_ff=128, in_dim=16,
                cond_dim=64, num_steps=4)


def test_forward_shapes():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 10, 16))
    cond = jax.random.normal(jax.random.PRNGKey(2), (3, 7, 64))
    v = dit_forward(CFG, p, x, jnp.full((3,), 0.5), cond)
    assert v.shape == (3, 10, 16)
    assert bool(jnp.isfinite(v).all())


def test_conditioning_matters():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    # zero-init out_proj means v==0 at init; nudge it so cond flows through
    p["out_proj"] = jnp.ones_like(p["out_proj"]) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 16))
    c1 = jax.random.normal(jax.random.PRNGKey(2), (1, 7, 64))
    c2 = jax.random.normal(jax.random.PRNGKey(3), (1, 7, 64))
    v1 = dit_forward(CFG, p, x, jnp.full((1,), 0.5), c1)
    v2 = dit_forward(CFG, p, x, jnp.full((1,), 0.5), c2)
    assert not np.allclose(np.asarray(v1), np.asarray(v2))


def test_sampler_deterministic_given_key():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    cond = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 64))
    k = jax.random.PRNGKey(5)
    a = sample(CFG, p, cond, 8, k)
    b = sample(CFG, p, cond, 8, k)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cache_interval_1_equals_exact():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    cond = jax.random.normal(jax.random.PRNGKey(2), (1, 5, 64))
    k = jax.random.PRNGKey(5)
    a = sample(CFG, p, cond, 8, k, cache_interval=1)
    b = sample(CFG, p, cond, 8, k)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_batches_same_bucket():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    eng = DiffusionEngine("d", CFG, p, max_batch=4)
    cond = np.random.randn(6, 64).astype(np.float32)
    for i in range(3):
        eng.enqueue(i, {"cond": cond, "out_len": 8})
    evs = eng.step()
    assert len(evs) == 3                       # one batch, three results
    assert eng.steps == 1
    for ev in evs:
        assert ev.kind == "finished"
        assert ev.payload["latent"].shape == (8, 16)


def test_engine_respects_max_batch():
    p = init_dit(CFG, jax.random.PRNGKey(0))
    eng = DiffusionEngine("d", CFG, p, max_batch=2)
    cond = np.random.randn(6, 64).astype(np.float32)
    for i in range(5):
        eng.enqueue(i, {"cond": cond, "out_len": 8})
    done = []
    while eng.has_work:
        done += eng.step()
    assert len(done) == 5
    assert eng.steps == 3                      # ceil(5/2)


def test_engine_mixed_chunk_shapes_in_queue():
    """Jobs with different cond lengths can coexist in the queue (a
    streaming talker's final short chunk lands among full-size chunks).
    The dequeue must remove by identity — a fieldwise job comparison
    would elementwise-compare mismatched cond arrays and raise."""
    p = init_dit(CFG, jax.random.PRNGKey(0))
    eng = DiffusionEngine("d", CFG, p, max_batch=4)
    short = np.random.randn(3, 64).astype(np.float32)
    full = np.random.randn(6, 64).astype(np.float32)
    eng.enqueue(0, {"cond": short, "out_len": 4,
                    "chunk_index": 1, "is_last_chunk": True})
    for i in range(1, 4):
        eng.enqueue(i, {"cond": full.copy(), "out_len": 8,
                        "chunk_index": 0, "is_last_chunk": False})
    done = []
    while eng.has_work:
        done += eng.step()
    assert sorted(ev.req_id for ev in done) == [0, 1, 2, 3]
    shapes = {ev.req_id: ev.payload["latent"].shape for ev in done}
    assert shapes[0] == (4, 16)
    assert all(shapes[i] == (8, 16) for i in (1, 2, 3))
