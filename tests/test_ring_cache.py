"""Property test: ring-buffer SWA decode == full forward for arbitrary
window / prompt-length / decode-step combinations."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.pipelines import tiny_lm
from repro.models import transformer as T

_CFG = tiny_lm("ring_t", vocab=128).replace(dtype="float32")
_PARAMS = T.init_params(_CFG, jax.random.PRNGKey(0))


@given(st.integers(4, 12),    # window
       st.integers(2, 24),    # prompt length
       st.integers(1, 6),     # decode steps
       st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_ring_swa_decode_matches_full(window, prompt_len, steps, seed):
    cfg = _CFG.replace(attn_variant="swa", sliding_window=window)
    toks = jax.random.randint(jax.random.PRNGKey(seed),
                              (1, prompt_len + steps), 0, cfg.vocab_size)
    full, _ = T.forward_full(cfg, _PARAMS, toks, remat=False)
    max_seq = prompt_len + steps + 2
    lo, cache = T.forward_prefill(cfg, _PARAMS, toks[:, :prompt_len],
                                  max_seq=max_seq, remat=False)
    # ring buffer engaged whenever window < max_seq
    if window < max_seq:
        assert cache["k"].shape[2] == window
    np.testing.assert_allclose(np.asarray(lo[:, -1]),
                               np.asarray(full[:, prompt_len - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(steps):
        pos = prompt_len + i
        lo, cache = T.forward_decode(cfg, _PARAMS, cache,
                                     toks[:, pos:pos + 1],
                                     jnp.array([pos]))
        np.testing.assert_allclose(np.asarray(lo[:, 0]),
                                   np.asarray(full[:, pos]),
                                   rtol=2e-3, atol=2e-3)
