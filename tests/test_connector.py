"""Connector round-trip + stats + channel API tests (incl. hypothesis
payload sweep), the deprecated put/get/delete shims, and the typed
TransferTimeout."""
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.connector.base import TransferTimeout
from repro.connector.mooncake import MooncakeConnector, make_connector


@pytest.mark.parametrize("kind", ["inline", "shm", "mooncake"])
def test_roundtrip_nested(kind):
    conn = make_connector(kind)
    payload = {"tokens": np.arange(7, dtype=np.int32),
               "hidden": np.random.randn(7, 16).astype(np.float32),
               "meta": {"n": 3, "name": "x"}}
    conn.send("k1", payload)
    got = conn.recv("k1", timeout=1.0)
    np.testing.assert_array_equal(got["tokens"], payload["tokens"])
    np.testing.assert_array_equal(got["hidden"], payload["hidden"])
    assert got["meta"] == payload["meta"]
    assert conn.stats.calls == 1
    assert conn.stats.bytes >= payload["tokens"].nbytes + payload["hidden"].nbytes
    assert conn.metadata("k1")["nbytes"] == conn.stats.bytes
    conn.release("k1")
    assert conn.metadata("k1") is None


@given(hnp.arrays(dtype=st.sampled_from([np.float32, np.int32, np.float16]),
                  shape=hnp.array_shapes(min_dims=1, max_dims=3,
                                         max_side=16)))
@settings(max_examples=40, deadline=None)
def test_roundtrip_arbitrary_arrays(arr):
    for kind in ("inline", "shm", "mooncake"):
        conn = make_connector(kind)
        conn.send("k", {"a": arr})
        got = conn.recv("k", timeout=1.0)["a"]
        conn.release("k")
        np.testing.assert_array_equal(np.asarray(got), arr)


def test_mooncake_cost_model():
    conn = MooncakeConnector(bandwidth_gbps=10.0, latency_s=1e-4)
    big = np.zeros((1000, 1000), np.float32)     # 4 MB
    conn.send("k", big)
    conn.recv("k", timeout=1.0)
    conn.release("k")
    # send + recv hops: 2 * (latency + 4e6/10e9)
    expected = 2 * (1e-4 + big.nbytes / 10e9)
    assert abs(conn.stats.modeled_time - expected) < 1e-6


def test_keys_are_independent():
    conn = make_connector("shm")
    conn.send("a", np.ones(3))
    conn.send("b", np.zeros(3))
    np.testing.assert_array_equal(conn.recv("a", timeout=1.0), np.ones(3))
    np.testing.assert_array_equal(conn.recv("b", timeout=1.0), np.zeros(3))
    conn.release("a")
    conn.release("b")


# ---- deprecated put/get/delete shims (one-release compatibility) ----------

def test_legacy_trio_warns_and_forwards_to_channel_api():
    conn = make_connector("shm")
    with pytest.warns(DeprecationWarning, match=r"put\(\) is deprecated"):
        conn.put("k", np.ones(3))              # noqa: DEP001 (shim test)
    assert conn.poll("k")                          # landed via send()
    with pytest.warns(DeprecationWarning, match=r"get\(\) is deprecated"):
        np.testing.assert_array_equal(
            conn.get("k"), np.ones(3))         # noqa: DEP001 (shim test)
    with pytest.warns(DeprecationWarning, match=r"delete\(\) is deprecated"):
        conn.delete("k")                       # noqa: DEP001 (shim test)
    assert conn.metadata("k") is None
    assert conn.resident_bytes == 0                # single accounting path


def test_legacy_get_missing_key_keeps_keyerror_contract():
    conn = make_connector("inline")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(KeyError):
            conn.get("never-sent")             # noqa: DEP001 (shim test)


# ---- typed TransferTimeout (key + edge attribution) -----------------------

def test_recv_timeout_is_typed_and_attributable():
    conn = make_connector("inline")
    with pytest.raises(TransferTimeout) as ei:
        conn.recv("missing", timeout=0.01)
    e = ei.value
    assert isinstance(e, TimeoutError)             # old catch sites survive
    assert e.key == "missing" and e.edge is None
    assert e.connector == "inline" and e.timeout == 0.01
    e2 = e.with_edge("prefill->decode")
    assert e2.key == "missing" and e2.edge == "prefill->decode"
    assert "prefill->decode" in str(e2) and "missing" in str(e2)


def test_transfer_timeout_fails_one_request_naming_the_edge():
    """A timed-out edge transfer fails ONLY the owning request, with the
    edge in the failure message; the stage worker keeps serving."""
    from repro.connector.shm import SharedMemoryConnector
    from repro.core.graph import StageGraph
    from repro.core.orchestrator import Orchestrator
    from repro.core.request import Request
    from repro.core.stage import StageSpec
    from repro.engine.stub_engine import make_stub

    class BlackholeConnector(SharedMemoryConnector):
        """send() publishes nowhere — every recv waits out its timeout."""

        def send(self, key, payload):
            from repro.connector.base import TransferHandle
            return TransferHandle(key=key, nbytes=0, t_send=time.time())

    graph = StageGraph()
    graph.add_stage(StageSpec("a", "custom"))
    graph.add_stage(StageSpec("b", "custom", is_output=True))
    graph.add_edge("a", "b", lambda d, p: p, connector="shm")
    from repro.core.config import ServeConfig
    orch = Orchestrator(graph, {"a": make_stub("a"), "b": make_stub("b")},
                        connectors={"shm": BlackholeConnector()},
                        config=ServeConfig(recv_timeout=0.05))
    orch.submit(Request(inputs={"x": 1}))
    done = orch.run(timeout=30.0)
    assert len(done) == 1 and done[0].failed
    assert "a->b" in done[0].failed and "timed out" in done[0].failed
    assert orch.worker_error is None       # the worker survived the timeout


# ---- async channel API (send -> handle, recv blocks, release evicts) ------

@pytest.mark.parametrize("kind", ["inline", "shm", "mooncake"])
def test_channel_recv_blocks_until_send(kind):
    import threading
    conn = make_connector(kind)
    got = {}

    def consumer():
        got["v"] = conn.recv("k", timeout=5.0)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.02)                       # consumer is already waiting
    handle = conn.send("k", {"a": np.arange(4, dtype=np.int32)})
    t.join(timeout=5.0)
    assert not t.is_alive()
    np.testing.assert_array_equal(got["v"]["a"], np.arange(4))
    assert handle.key == "k" and handle.nbytes >= 16
    assert conn.poll("k")
    conn.release("k")
    assert not conn.poll("k") and conn.metadata("k") is None


def test_channel_recv_timeout():
    conn = make_connector("inline")
    with pytest.raises(TimeoutError):
        conn.recv("never-sent", timeout=0.01)


def test_shm_pool_accounting_tracks_lifetimes():
    conn = make_connector("shm")
    conn.send("a", np.ones(100, np.float64))           # 800 B resident
    conn.send("b", np.ones(50, np.float64))            # +400 B
    assert conn.resident_bytes == 1200
    conn.release("a")
    assert conn.resident_bytes == 400
    assert conn.peak_resident_bytes == 1200
    conn.release("b")
    assert conn.resident_bytes == 0


def test_mooncake_resident_object_accounting():
    conn = MooncakeConnector()
    conn.send("a", np.ones(3))
    conn.send("b", np.ones(3))
    conn.release("a")
    assert conn.resident_objects == 1
    assert conn.peak_resident_objects == 2
