"""Connector round-trip + stats + async channel tests (incl. hypothesis
payload sweep)."""
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.connector.mooncake import MooncakeConnector, make_connector


@pytest.mark.parametrize("kind", ["inline", "shm", "mooncake"])
def test_roundtrip_nested(kind):
    conn = make_connector(kind)
    payload = {"tokens": np.arange(7, dtype=np.int32),
               "hidden": np.random.randn(7, 16).astype(np.float32),
               "meta": {"n": 3, "name": "x"}}
    conn.put("k1", payload)
    got = conn.get("k1")
    np.testing.assert_array_equal(got["tokens"], payload["tokens"])
    np.testing.assert_array_equal(got["hidden"], payload["hidden"])
    assert got["meta"] == payload["meta"]
    assert conn.stats.calls == 1
    assert conn.stats.bytes >= payload["tokens"].nbytes + payload["hidden"].nbytes
    assert conn.metadata("k1")["nbytes"] == conn.stats.bytes
    conn.delete("k1")
    assert conn.metadata("k1") is None


@given(hnp.arrays(dtype=st.sampled_from([np.float32, np.int32, np.float16]),
                  shape=hnp.array_shapes(min_dims=1, max_dims=3,
                                         max_side=16)))
@settings(max_examples=40, deadline=None)
def test_roundtrip_arbitrary_arrays(arr):
    for kind in ("inline", "shm", "mooncake"):
        conn = make_connector(kind)
        conn.put("k", {"a": arr})
        got = conn.get("k")["a"]
        np.testing.assert_array_equal(np.asarray(got), arr)


def test_mooncake_cost_model():
    conn = MooncakeConnector(bandwidth_gbps=10.0, latency_s=1e-4)
    big = np.zeros((1000, 1000), np.float32)     # 4 MB
    conn.put("k", big)
    conn.get("k")
    # put + get hops: 2 * (latency + 4e6/10e9)
    expected = 2 * (1e-4 + big.nbytes / 10e9)
    assert abs(conn.stats.modeled_time - expected) < 1e-6


def test_keys_are_independent():
    conn = make_connector("shm")
    conn.put("a", np.ones(3))
    conn.put("b", np.zeros(3))
    np.testing.assert_array_equal(conn.get("a"), np.ones(3))
    np.testing.assert_array_equal(conn.get("b"), np.zeros(3))


# ---- async channel API (send -> handle, recv blocks, release evicts) ------

@pytest.mark.parametrize("kind", ["inline", "shm", "mooncake"])
def test_channel_recv_blocks_until_send(kind):
    import threading
    conn = make_connector(kind)
    got = {}

    def consumer():
        got["v"] = conn.recv("k", timeout=5.0)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.02)                       # consumer is already waiting
    handle = conn.send("k", {"a": np.arange(4, dtype=np.int32)})
    t.join(timeout=5.0)
    assert not t.is_alive()
    np.testing.assert_array_equal(got["v"]["a"], np.arange(4))
    assert handle.key == "k" and handle.nbytes >= 16
    assert conn.poll("k")
    conn.release("k")
    assert not conn.poll("k") and conn.metadata("k") is None


def test_channel_recv_timeout():
    conn = make_connector("inline")
    with pytest.raises(TimeoutError):
        conn.recv("never-sent", timeout=0.01)


def test_shm_pool_accounting_tracks_lifetimes():
    conn = make_connector("shm")
    conn.send("a", np.ones(100, np.float64))           # 800 B resident
    conn.send("b", np.ones(50, np.float64))            # +400 B
    assert conn.resident_bytes == 1200
    conn.release("a")
    assert conn.resident_bytes == 400
    assert conn.peak_resident_bytes == 1200
    conn.release("b")
    assert conn.resident_bytes == 0


def test_mooncake_resident_object_accounting():
    conn = MooncakeConnector()
    conn.send("a", np.ones(3))
    conn.send("b", np.ones(3))
    conn.release("a")
    assert conn.resident_objects == 1
    assert conn.peak_resident_objects == 2
