"""Stage-graph structural tests (incl. hypothesis random-DAG property)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import StageGraph
from repro.core.stage import StageSpec


def _g():
    g = StageGraph()
    g.add_stage(StageSpec("a", "ar"))
    g.add_stage(StageSpec("b", "ar"))
    g.add_stage(StageSpec("c", "diffusion", is_output=True))
    g.add_edge("a", "b", lambda d, p: p)
    g.add_edge("b", "c", lambda d, p: p, streaming=True)
    return g


def test_topo_and_sources():
    g = _g()
    order = g.topo_order()
    assert order.index("a") < order.index("b") < order.index("c")
    assert g.sources() == ["a"]
    assert g.output_stages() == ["c"]
    g.validate()


def test_cycle_detection():
    g = _g()
    g.add_edge("c", "a", lambda d, p: p)
    with pytest.raises(ValueError, match="cycle"):
        g.topo_order()


def test_duplicate_stage_rejected():
    g = _g()
    with pytest.raises(ValueError, match="duplicate"):
        g.add_stage(StageSpec("a", "ar"))


def test_unknown_edge_rejected():
    g = _g()
    with pytest.raises(ValueError, match="unknown"):
        g.add_edge("a", "zzz", lambda d, p: p)


def test_default_outputs_are_sinks():
    g = StageGraph()
    g.add_stage(StageSpec("x", "ar"))
    g.add_stage(StageSpec("y", "ar"))
    g.add_edge("x", "y", lambda d, p: p)
    assert g.output_stages() == ["y"]


def test_bad_kind_rejected():
    with pytest.raises(AssertionError):
        StageSpec("x", "warp-speed")


@given(st.integers(1, 8), st.data())
@settings(max_examples=60, deadline=None)
def test_random_dag_topo_property(n, data):
    """Any random forward-edge graph validates; topo order respects every
    edge; adding a back edge creates a detected cycle."""
    g = StageGraph()
    for i in range(n):
        g.add_stage(StageSpec(f"s{i}", "ar"))
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if data.draw(st.booleans()):
                g.add_edge(f"s{i}", f"s{j}", lambda d, p: p)
                edges.append((i, j))
    order = g.topo_order()
    assert sorted(order) == sorted(f"s{i}" for i in range(n))
    pos = {s: k for k, s in enumerate(order)}
    for i, j in edges:
        assert pos[f"s{i}"] < pos[f"s{j}"]
    g.validate()
    if edges:
        i, j = edges[data.draw(st.integers(0, len(edges) - 1))]
        g.add_edge(f"s{j}", f"s{i}", lambda d, p: p)   # back edge
        with pytest.raises(ValueError, match="cycle"):
            g.topo_order()
