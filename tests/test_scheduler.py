"""Property-based tests of the continuous-batching scheduler invariants."""
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.engine.kv_cache import PageAllocator, PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.engine.scheduler import Scheduler


@given(st.lists(st.integers(1, 40), min_size=1, max_size=30),
       st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_page_accounting_conserved(prompt_lens, max_batch):
    kv = PagedKVConfig(num_pages=64, page_size=8, max_pages_per_seq=16)
    sched = Scheduler(kv, max_batch=max_batch, token_budget=64, chunk_size=16)
    for i, pl in enumerate(prompt_lens):
        sched.add(i, pl, SamplingParams(max_new_tokens=4))
    for _ in range(3000):
        if not sched.has_work:
            break
        plan = sched.schedule()
        assert sched.allocator.check_invariant()
        if not plan.prefill_chunks and not plan.decode_req_ids:
            break
        for ch in plan.prefill_chunks:
            sched.note_prefill(ch.req_id, ch.length)
            seq = sched.running[ch.req_id]
            if not seq.in_prefill:
                if sched.note_sampled(ch.req_id, 0):
                    sched.release(ch.req_id)
        for rid in list(plan.decode_req_ids):
            if rid not in sched.running or sched.running[rid].finished:
                continue
            sched.note_decode_written(rid)
            if sched.note_sampled(rid, 1):
                sched.release(rid)
    # drained: every page back in the pool
    assert not sched.running
    assert sched.allocator.free_pages == kv.num_pages
    assert sched.allocator.check_invariant()


@given(st.lists(st.integers(1, 30), min_size=2, max_size=20))
@settings(max_examples=30, deadline=None)
def test_fifo_admission(prompt_lens):
    kv = PagedKVConfig(num_pages=32, page_size=8, max_pages_per_seq=8)
    sched = Scheduler(kv, max_batch=4, token_budget=64, chunk_size=16)
    for i, pl in enumerate(prompt_lens):
        sched.add(i, pl, SamplingParams(max_new_tokens=2))
    admitted = []
    for _ in range(2000):
        if not sched.has_work:
            break
        plan = sched.schedule()
        admitted.extend(plan.admitted)
        if not plan.prefill_chunks and not plan.decode_req_ids:
            break
        for ch in plan.prefill_chunks:
            sched.note_prefill(ch.req_id, ch.length)
            if not sched.running[ch.req_id].in_prefill:
                if sched.note_sampled(ch.req_id, 0):
                    sched.release(ch.req_id)
        for rid in list(plan.decode_req_ids):
            if rid in sched.running and not sched.running[rid].finished:
                sched.note_decode_written(rid)
                if sched.note_sampled(rid, 1):
                    sched.release(rid)
    assert admitted == sorted(admitted), "admission must be FIFO"
    assert admitted == list(range(len(prompt_lens))), "no starvation"


@given(st.integers(8, 64), st.integers(1, 6), st.integers(1, 100))
@settings(max_examples=30, deadline=None)
def test_token_budget_respected(budget, max_batch, seed):
    import random
    r = random.Random(seed)
    kv = PagedKVConfig(num_pages=128, page_size=8, max_pages_per_seq=16)
    sched = Scheduler(kv, max_batch=max_batch, token_budget=budget,
                      chunk_size=16)
    for i in range(10):
        sched.add(i, r.randint(1, 60), SamplingParams(max_new_tokens=3))
    for _ in range(1000):
        if not sched.has_work:
            break
        plan = sched.schedule()
        if not plan.prefill_chunks and not plan.decode_req_ids:
            break
        # prefill tokens never exceed what decode left in the budget
        prefill_toks = sum(c.length for c in plan.prefill_chunks)
        assert prefill_toks <= max(0, budget - len(plan.decode_req_ids)) \
            or prefill_toks == 0
        for ch in plan.prefill_chunks:
            sched.note_prefill(ch.req_id, ch.length)
            if not sched.running[ch.req_id].in_prefill:
                if sched.note_sampled(ch.req_id, 0):
                    sched.release(ch.req_id)
        for rid in list(plan.decode_req_ids):
            if rid in sched.running and not sched.running[rid].finished:
                sched.note_decode_written(rid)
                if sched.note_sampled(rid, 1):
                    sched.release(rid)


def test_allocator_basics():
    a = PageAllocator(10)
    p1 = a.allocate(1, 4)
    p2 = a.allocate(2, 6)
    assert p1 and p2 and a.free_pages == 0
    assert a.allocate(3, 1) is None
    a.free(1)
    assert a.free_pages == 4
    assert a.check_invariant()
    a.free(2)
    assert a.free_pages == 10
