"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed with interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba1_scan
from repro.kernels.paged_attention import paged_attention

KEYS = jax.random.split(jax.random.PRNGKey(7), 16)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,nq,nkv,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 8, 1, 128),    # MQA
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(b, s, nq, nkv, hd, causal, window, dtype):
    q = jax.random.normal(KEYS[0], (b, s, nq, hd), dtype)
    k = jax.random.normal(KEYS[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(KEYS[2], (b, s, nkv, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nq,nkv,hd,page,pp", [
    (2, 8, 2, 64, 8, 4),
    (3, 4, 4, 128, 16, 2),
    (1, 16, 2, 64, 8, 8),
])
@pytest.mark.parametrize("window", [0, 16])
def test_paged_attention_sweep(b, nq, nkv, hd, page, pp, window, dtype):
    P = b * pp + 2
    q = jax.random.normal(KEYS[3], (b, nq, hd), dtype)
    kp = jax.random.normal(KEYS[4], (P, page, nkv, hd), dtype)
    vp = jax.random.normal(KEYS[5], (P, page, nkv, hd), dtype)
    bt = jax.random.permutation(KEYS[6], P)[:b * pp].reshape(b, pp)
    bt = bt.astype(jnp.int32)
    max_len = page * pp
    sl = jax.random.randint(KEYS[7], (b,), 1, max_len + 1).astype(jnp.int32)
    got = paged_attention(q, kp, vp, bt, sl, window=window, interpret=True)
    want = ref.paged_attention(q, kp, vp, bt, sl, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_attention_int8_dequant():
    """Quantized page pool with in-kernel dequant vs dequantized-ref."""
    b, nq, nkv, hd, page, pp = 2, 8, 2, 64, 8, 4
    P = b * pp + 2
    q = jax.random.normal(KEYS[3], (b, nq, hd), jnp.float32)
    kf = jax.random.normal(KEYS[4], (P, page, nkv, hd), jnp.float32)
    vf = jax.random.normal(KEYS[5], (P, page, nkv, hd), jnp.float32)

    def quant(x):
        s = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-8
        return jnp.round(x / s[..., None]).astype(jnp.int8), s
    kq, ks = quant(kf)
    vq, vs = quant(vf)
    bt = jax.random.permutation(KEYS[6], P)[:b * pp].reshape(b, pp)
    bt = bt.astype(jnp.int32)
    sl = jnp.array([13, 29], jnp.int32)
    got = paged_attention(q, kq, vq, bt, sl, k_scale_pages=ks,
                          v_scale_pages=vs, interpret=True)
    want = ref.paged_attention(q, kq, vq, bt, sl, k_scale_pages=ks,
                               v_scale_pages=vs)
    exact = ref.paged_attention(q, kf, vf, bt, sl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # and close to the unquantized result
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                               rtol=0.05, atol=0.05)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bt,s,di,n", [(1, 64, 128, 8), (2, 128, 256, 16)])
def test_mamba_scan_sweep(bt, s, di, n, dtype):
    x = (jax.random.normal(KEYS[8], (bt, s, di)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(KEYS[9], (bt, s, di))) * 0.1
          ).astype(dtype)
    A = -jnp.exp(jax.random.normal(KEYS[10], (di, n)) * 0.3)
    B = jax.random.normal(KEYS[11], (bt, s, n)).astype(dtype)
    C = jax.random.normal(KEYS[12], (bt, s, n)).astype(dtype)
    D = jnp.ones((di,))
    y1, h1 = mamba1_scan(x, dt, A, B, C, D, bd=128, bs=32, interpret=True)
    y2, h2 = ref.mamba1_scan(x, dt, A, B, C, D)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-2,
                               atol=1e-2)


def test_mamba_scan_state_continuation():
    """Scanning two halves with carried state == scanning the whole."""
    bt, s, di, n = 1, 64, 128, 8
    x = jax.random.normal(KEYS[13], (bt, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(KEYS[14], (bt, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(KEYS[15], (di, n)) * 0.3)
    B = jax.random.normal(KEYS[0], (bt, s, n))
    C = jax.random.normal(KEYS[1], (bt, s, n))
    D = jnp.ones((di,))
    y_full, h_full = ref.mamba1_scan(x, dt, A, B, C, D)
    h = None
    ys = []
    for lo, hi in ((0, 32), (32, 64)):
        y, h = mamba1_scan(x[:, lo:hi], dt[:, lo:hi], A, B[:, lo:hi],
                           C[:, lo:hi], D, h, bd=128, bs=32, interpret=True)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_trainable_grads():
    """jax.grad through the Pallas kernel (custom VJP, recompute backward)
    must match grads of the oracle."""
    from repro.kernels import ops
    b, s, nq, nkv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(KEYS[5], (b, s, nq, hd))
    k = jax.random.normal(KEYS[6], (b, s, nkv, hd))
    v = jax.random.normal(KEYS[7], (b, s, nkv, hd))

    def loss_kernel(q, k, v):
        return jnp.sum(ops.flash_attention_trainable(q, k, v, True, 0) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention(q, k, v, causal=True) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def test_chunk_attention_matches_flash():
    """chunk_attention over a full history == flash_attention causal."""
    b, s, nq, nkv, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(KEYS[2], (b, s, nq, hd))
    k = jax.random.normal(KEYS[3], (b, s, nkv, hd))
    v = jax.random.normal(KEYS[4], (b, s, nkv, hd))
    want = ref.flash_attention(q, k, v, causal=True)
    got = ref.chunk_attention(q, k, v, jnp.zeros((b,), jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
