"""Prefill-Decode disaggregation (paper §3.4): prompt KV computed on the
prefill engine, shipped through the unified connector, injected into the
decode engine's page pool — must reproduce the unified engine's greedy
output EXACTLY."""
import numpy as np
import pytest

from repro.configs.pipelines import build_pd_disaggregated, tiny_lm, _kv
from repro.core.config import ServeConfig, StageConfig
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T
import jax


@pytest.fixture(scope="module")
def pd():
    return build_pd_disaggregated(max_batch=4, max_new=8)


def _unified_tokens(cfg, params, prompts, max_new):
    eng = AREngine("u", cfg, params, kv=_kv(4), max_batch=4,
                   default_sampling=SamplingParams(max_new_tokens=max_new,
                                                   temperature=0.0))
    for i, p in enumerate(prompts):
        eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
    out = {}
    for _ in range(500):
        for ev in eng.step():
            if ev.kind == "finished":
                out[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    return out


def test_pd_matches_unified_greedy(pd):
    graph, engines, bundle = pd
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 500, size=n).astype(np.int32)
               for n in (5, 19, 33, 12)]
    orch = Orchestrator(graph, engines)
    reqs = [Request(inputs={"tokens": p}) for p in prompts]
    for r in reqs:
        orch.submit(r)
    done = orch.run()
    assert len(done) == 4
    want = _unified_tokens(bundle["cfg"], bundle["params"], prompts, 8)
    for i, r in enumerate(reqs):
        got = list(r.outputs["decode"][0]["tokens"])
        assert got == want[i], (i, got, want[i])
        # decode stage emits all 8 tokens incl. the prefill-sampled first
        assert len(got) == 8


def test_pd_process_isolated_decode_matches_unified(pd):
    """Acceptance: a pipeline with one ``isolation='process'`` stage
    produces byte-identical greedy outputs to the all-thread run.  The
    spawned decode replica rebuilds its AREngine from the bundle's
    EngineSpec (same seed → same params); prompt KV still travels
    prefill → decode through the shm connector, now across a real
    process boundary."""
    graph, engines, bundle = pd
    config = ServeConfig(stages={"decode": StageConfig(
        isolation="process", engine_spec=bundle["engine_specs"]["decode"])})
    orch = Orchestrator(graph, engines, config=config)
    assert orch._proc_replicas == {"decode": 1}
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 500, size=n).astype(np.int32)
               for n in (5, 19, 33, 12)]
    reqs = [Request(inputs={"tokens": p}) for p in prompts]
    for r in reqs:
        orch.submit(r)
    done = orch.run(timeout=300.0)
    assert len(done) == 4 and not any(r.failed for r in done)
    want = _unified_tokens(bundle["cfg"], bundle["params"], prompts, 8)
    for i, r in enumerate(reqs):
        got = list(r.outputs["decode"][0]["tokens"])
        assert got == want[i], (i, got, want[i])
    m = orch.stage_metrics()["decode"]
    assert m["finished"] == 4 and m["replica_failures"] == 0


def test_pd_kv_travels_through_connector(pd):
    graph, engines, bundle = pd
    orch = Orchestrator(graph, engines)
    orch.submit(Request(
        inputs={"tokens": np.arange(16, dtype=np.int32)}))
    orch.run()
    st = orch.connector_stats()["shm"]
    cfg = bundle["cfg"]
    # the KV payload must dominate: >= L*S*kvh*hd*2(kv)*4bytes for 16 tokens
    kv_bytes = cfg.num_layers * 16 * cfg.num_kv_heads * 32 * 2 * 4
    assert st.bytes >= kv_bytes


def test_epd_three_way_disaggregation():
    """Encoder -> Prefill -> Decode, MM cache + prompt KV both through the
    connector; output must match a unified engine fed the same encoder
    embeddings."""
    from repro.configs.pipelines import build_epd_disaggregated
    graph, engines, bundle = build_epd_disaggregated(max_batch=2, max_new=6)
    rng = np.random.default_rng(0)
    frames = [rng.standard_normal((n, 32)).astype(np.float32)
              for n in (7, 15)]
    orch = Orchestrator(graph, engines)
    reqs = [Request(inputs={"frames": f}) for f in frames]
    for r in reqs:
        orch.submit(r)
    done = orch.run()
    assert len(done) == 2
    # unified reference: one engine, prompt embeddings from the encoder
    cfg, params, w_enc = bundle["cfg"], bundle["params"], bundle["w_enc"]
    eng = AREngine("u", cfg, params, kv=_kv(2), max_batch=2,
                   default_sampling=SamplingParams(max_new_tokens=6,
                                                   temperature=0.0))
    for i, f in enumerate(frames):
        eng.enqueue(i, {"prompt_embeds": f @ w_enc}, SamplingParams(), {})
    want = {}
    for _ in range(300):
        for ev in eng.step():
            if ev.kind == "finished":
                want[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    for i, r in enumerate(reqs):
        got = list(r.outputs["decode"][0]["tokens"])
        assert got == want[i], (i, got, want[i])
    # both hops used the connector
    assert orch.connector_stats()["shm"].calls >= 4


def test_pd_stages_run_disjoint_workloads(pd):
    graph, engines, bundle = pd
    orch = Orchestrator(graph, engines)
    for i in range(3):
        orch.submit(Request(
            inputs={"tokens": np.arange(10 + i, dtype=np.int32)}))
    orch.run()
    # prefill engine never decodes (1 sampled token/req => few steps);
    # decode engine never prefills
    assert engines["decode"].steps >= 7        # ~7 decode iterations
    sched = engines["decode"].scheduler
    assert not sched.running and not sched.waiting


def test_int8_kv_extract_inject_roundtrip():
    """PD transfer with int8 page pools: the prefill engine dequantizes to
    float for the wire, the decode engine re-quantizes on injection.  A
    decode step against the injected pages must match one against the
    locally-prefilled pages (re-quantizing already-quantized values is a
    near-fixed-point, so logits agree to int8 tolerance)."""
    from repro.engine.kv_cache import PagedKVConfig
    from repro.engine.runner import PagedRunner

    cfg = tiny_lm("t8", vocab=256).replace(kv_cache_dtype="int8")
    params = T.init_params(cfg, jax.random.PRNGKey(7))
    kv = PagedKVConfig(num_pages=16, page_size=8, max_pages_per_seq=8)
    r1, r2 = PagedRunner(cfg, params, kv), PagedRunner(cfg, params, kv)
    assert r1.k_pages.dtype == np.int8 and r1.k_scales is not None

    n = 24                                     # 3 full pages
    prompt = np.arange(1, n + 1, dtype=np.int32) % 256
    bt1 = np.array([0, 1, 2, 3, 0, 0, 0, 0], np.int32)
    bt2 = np.array([9, 10, 11, 12, 0, 0, 0, 0], np.int32)   # distinct pages
    embeds = r1.embed(prompt)[None].astype(np.float32)
    logits, _ = r1.prefill_chunk(embeds, bt1, 0, n)
    t0 = int(np.argmax(np.asarray(logits)[n - 1]))

    k, v = r1.extract_kv(bt1, n)
    assert k.dtype == np.float32 and k.shape[1] == n
    r2.inject_kv(k, v, bt2, n)

    dec = r1.embed(np.array([t0], np.int32))[None].astype(np.float32)
    pos = np.array([n], np.int32)
    act = np.array([True])
    l1, _ = r1.decode(dec, bt1[None], pos, act)
    l2, _ = r2.decode(dec, bt2[None], pos, act)
    l1, l2 = np.asarray(l1)[0], np.asarray(l2)[0]
    assert int(np.argmax(l1)) == int(np.argmax(l2))
    np.testing.assert_allclose(l1, l2, rtol=0, atol=5e-3)
