"""ServeConfig API: eager validation, the argparse funnel, the
deprecated Orchestrator kwargs shim, and EngineSpec pickling/rebuild.

Pure-python config objects plus stub engines — fast tier.
"""
import argparse
import pickle

import pytest

from repro.core.config import (EngineSpec, ServeConfig, StageConfig,
                               _parse_stage_map)
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.stub_engine import StubEngine, make_stub


def _graph():
    g = StageGraph()
    g.add_stage(StageSpec("s", "custom", is_output=True))
    return g


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_defaults_valid():
    cfg = ServeConfig()
    assert cfg.backend == "threaded"
    assert cfg.stage("anything") == StageConfig()
    assert cfg.stage_routing("anything") == "affinity"


@pytest.mark.parametrize("kwargs", [
    {"backend": "celery"},
    {"queue_capacity": 0},
    {"recv_timeout": 0.0},
    {"routing": "psychic"},
])
def test_bad_top_level_values_raise(kwargs):
    with pytest.raises(ValueError):
        ServeConfig(**kwargs)


def test_bad_stage_values_raise():
    with pytest.raises(ValueError):
        StageConfig(replicas=0)
    with pytest.raises(ValueError):
        StageConfig(isolation="container")
    with pytest.raises(ValueError):
        StageConfig(routing="psychic")
    with pytest.raises(TypeError):
        ServeConfig(stages={"s": {"replicas": 2}})


def test_process_isolation_requires_engine_spec():
    with pytest.raises(ValueError, match="engine_spec"):
        StageConfig(isolation="process")
    spec = EngineSpec("repro.engine.stub_engine:make_stub", {"name": "s"})
    sc = StageConfig(isolation="process", engine_spec=spec)
    assert sc.engine_spec is spec


def test_sync_backend_rejects_replicas_and_process():
    with pytest.raises(ValueError, match="single-replica"):
        ServeConfig(backend="sync", stages={"s": StageConfig(replicas=2)})
    spec = EngineSpec("repro.engine.stub_engine:make_stub", {})
    with pytest.raises(ValueError, match="cannot isolate"):
        ServeConfig(backend="sync", stages={"s": StageConfig(
            isolation="process", engine_spec=spec)})


def test_config_is_immutable():
    cfg = ServeConfig(stages={"s": StageConfig(replicas=2)})
    with pytest.raises(Exception):
        cfg.backend = "sync"
    with pytest.raises(TypeError):
        cfg.stages["t"] = StageConfig()


def test_with_stage_copies():
    cfg = ServeConfig(stages={"s": StageConfig(replicas=2)})
    cfg2 = cfg.with_stage("s", replicas=3).with_stage("t", routing="round_robin")
    assert cfg.stage("s").replicas == 2          # original untouched
    assert cfg2.stage("s").replicas == 3
    assert cfg2.stage_routing("t") == "round_robin"
    assert cfg2.stage_routing("s") == "affinity"  # inherited default


# ---------------------------------------------------------------------------
# EngineSpec
# ---------------------------------------------------------------------------

def test_engine_spec_target_must_have_colon():
    with pytest.raises(ValueError, match="module:callable"):
        EngineSpec("repro.engine.stub_engine.make_stub")


def test_engine_spec_builds_and_pickles():
    spec = EngineSpec("repro.engine.stub_engine:make_stub",
                      {"name": "worker", "dwell_ms": 0.0})
    eng = spec.build()
    assert isinstance(eng, StubEngine) and eng.name == "worker"
    clone = pickle.loads(pickle.dumps(spec))
    assert clone == spec
    assert isinstance(clone.build(), StubEngine)


# ---------------------------------------------------------------------------
# from_args (the argparse funnel)
# ---------------------------------------------------------------------------

def test_from_args_round_trip():
    ns = argparse.Namespace(
        backend="threaded", queue_capacity=16, recv_timeout=5.0,
        replicas="a=2,b=3", routing="least_loaded",
        isolation="b=process", warm_seed=False)
    spec = EngineSpec("repro.engine.stub_engine:make_stub", {})
    cfg = ServeConfig.from_args(ns, engine_specs={"b": spec})
    assert cfg.queue_capacity == 16 and cfg.recv_timeout == 5.0
    assert cfg.warm_seed is False
    assert cfg.stage("a").replicas == 2
    assert cfg.stage("b").replicas == 3
    assert cfg.stage("a").isolation == "thread"
    assert cfg.stage("b").isolation == "process"
    assert cfg.stage("b").engine_spec is spec


def test_from_args_bare_isolation_applies_to_all():
    ns = argparse.Namespace(replicas="a=1,b=1", isolation="process")
    spec = EngineSpec("repro.engine.stub_engine:make_stub", {})
    cfg = ServeConfig.from_args(ns, engine_specs={"a": spec, "b": spec})
    assert all(cfg.stage(s).isolation == "process" for s in ("a", "b"))


def test_from_args_partial_namespace_uses_defaults():
    cfg = ServeConfig.from_args(argparse.Namespace())
    assert cfg == ServeConfig()


def test_parse_stage_map_rejects_bare_values():
    with pytest.raises(ValueError, match="STAGE=VALUE"):
        _parse_stage_map("talker2", int, "replicas")
    assert _parse_stage_map("a=2, b=3", int, "replicas") == {"a": 2, "b": 3}


# ---------------------------------------------------------------------------
# deprecated Orchestrator kwargs shim (one-release compatibility)
# ---------------------------------------------------------------------------

def test_legacy_kwargs_bag_warns_and_still_works():
    with pytest.warns(DeprecationWarning, match="kwargs bag is deprecated"):
        orch = Orchestrator(
            _graph(), {"s": make_stub("s")},
            replicas={"s": 2},                 # noqa: DEP002 (shim test)
            engine_factories={"s": lambda: make_stub("s")})  # noqa: DEP002
    assert orch.config.stage("s").replicas == 2
    orch.submit(Request(inputs={"x": 1}))
    done = orch.run()
    assert len(done) == 1 and not done[0].failed


def test_bare_backend_kwarg_does_not_warn():
    import warnings as w
    with w.catch_warnings():
        w.simplefilter("error", DeprecationWarning)
        orch = Orchestrator(_graph(), {"s": make_stub("s")}, backend="sync")
    assert orch.backend == "sync"


def test_config_plus_legacy_kwargs_is_an_error():
    with pytest.raises(TypeError, match="not both"):
        Orchestrator(
            _graph(), {"s": make_stub("s")},
            config=ServeConfig(),
            routing="round_robin")             # noqa: DEP002 (shim test)


def test_unknown_kwarg_is_an_error():
    with pytest.raises(TypeError, match="unexpected keyword"):
        Orchestrator(_graph(), {"s": make_stub("s")}, replica_count=2)


def test_replica_spec_for_unknown_stage_rejected():
    with pytest.raises(ValueError, match="unknown stage"):
        Orchestrator(_graph(), {"s": make_stub("s")},
                     config=ServeConfig(stages={"t": StageConfig(replicas=2)}))
