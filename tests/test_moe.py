"""MoE layer: routing correctness, capacity dropping, load-balance loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import moe


def _cfg(cf=1e9):
    return get_config("mixtral_8x7b", smoke=True).replace(
        dtype="float32", capacity_factor=cf)


def test_lossless_routing_matches_explicit():
    """With no dropping, the sort-based dispatch must equal an explicit
    per-token top-k expert sum."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    y, aux = moe.moe_forward(cfg, p, x)

    # explicit reference
    xf = x.reshape(-1, cfg.d_model)
    gates = jax.nn.softmax(xf @ p["router"], axis=-1)
    topw, topi = jax.lax.top_k(gates, cfg.experts_per_token)
    topw = topw / topw.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.experts_per_token):
            e = int(topi[t, j])
            h = jax.nn.silu(xf[t] @ p["wg"][e]) * (xf[t] @ p["wu"][e])
            acc = acc + topw[t, j] * (h @ p["wd"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


def test_capacity_drops_tokens():
    cfg = _cfg(cf=1e9)
    tight = cfg.replace(capacity_factor=0.01)   # capacity floor = 8 slots
    key = jax.random.PRNGKey(0)
    p = moe.init_moe(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model))
    y_full, _ = moe.moe_forward(cfg, p, x)
    y_tight, _ = moe.moe_forward(tight, p, x)
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))
    assert np.isfinite(np.asarray(y_tight)).all()


def test_aux_loss_prefers_balance():
    """Fully concentrated routing must pay ~E/k x the balanced aux loss.

    Balanced: f_e = P_e = 1/E  => aux = coef * E * (1/E) = coef.
    All mass on k experts:      => aux ~= coef * E / (2k) * ... > coef.
    """
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = dict(moe.init_moe(cfg, key))
    E, k = cfg.num_experts, cfg.experts_per_token
    # constant inputs + crafted router => every token routes to experts {0,1}
    router = jnp.zeros((cfg.d_model, E))
    router = router.at[:, 0].set(10.0 / cfg.d_model)
    router = router.at[:, 1].set(9.0 / cfg.d_model)
    p["router"] = router
    x = jnp.ones((2, 32, cfg.d_model))
    _, aux_skew = moe.moe_forward(cfg, p, x)
    balanced = cfg.router_aux_coef          # analytic balanced value
    assert float(aux_skew) > 1.5 * balanced


def test_capacity_fn():
    cfg = _cfg().replace(capacity_factor=1.25)
    c = moe.capacity(1024, cfg)
    assert c == int(np.ceil(1024 * cfg.experts_per_token
                            / cfg.num_experts * 1.25))
