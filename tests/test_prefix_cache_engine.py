"""Engine-level prefix caching: reused KV pages must be invisible in the
outputs.  Cached pages hold exactly the K/V a fresh prefill would compute
(causal attention + identical chunk boundaries), so greedy generations are
byte-identical with the cache on and off — on token stages, embed-fed
stages (Thinker -> Talker), and across multi-turn context reuse.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.configs.pipelines import tiny_lm
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def _engine(cfg, params, **kw):
    kv = PagedKVConfig(num_pages=64, page_size=8, max_pages_per_seq=16)
    defaults = dict(kv=kv, max_batch=4, token_budget=64, chunk_size=16)
    defaults.update(kw)
    return AREngine("eng", cfg, params, **defaults)


def _run_sequential(eng, inputs_list):
    """One request at a time (each publishes before the next admits)."""
    results = {}
    for i, inp in enumerate(inputs_list):
        eng.enqueue(i, inp, SamplingParams(), {})
        for _ in range(500):
            for ev in eng.step():
                if ev.kind == "finished":
                    results[ev.req_id] = list(ev.payload["tokens"])
            assert eng.scheduler.allocator.check_invariant()
            if not eng.has_work:
                break
    return results


def _greedy_reference(cfg, params, prompt, n_new, max_seq=256):
    toks = jnp.asarray(prompt)[None]
    logits, cache = T.forward_prefill(cfg, params, toks, max_seq,
                                      remat=False)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        t = jnp.array([[out[-1]]], jnp.int32)
        logits, cache = T.forward_decode(cfg, params, cache, t,
                                         jnp.array([pos]))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return out


@pytest.fixture(scope="module")
def lm():
    cfg = tiny_lm("t", vocab=256)
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    return cfg, params


def _engines_on_off(cfg, params, n_new):
    sp = SamplingParams(max_new_tokens=n_new, temperature=0.0)
    return (_engine(cfg, params, enable_prefix_cache=True,
                    default_sampling=sp),
            _engine(cfg, params, enable_prefix_cache=False,
                    default_sampling=sp))


def test_token_stage_cached_suffix_matches_full_prefill(lm):
    cfg, params = lm
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 256, 20).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(0, 256, n)
                               .astype(np.int32)]) for n in (5, 9, 1)]
    on, off = _engines_on_off(cfg, params, n_new=6)
    got_on = _run_sequential(on, [{"tokens": p} for p in prompts])
    got_off = _run_sequential(off, [{"tokens": p} for p in prompts])
    assert got_on == got_off
    for i, p in enumerate(prompts):
        assert got_on[i] == _greedy_reference(cfg, params, p, 6)
    st = on.prefix_stats
    # requests 2 and 3 hit the 2 full shared pages (16 of 20 tokens) AND
    # the 4 non-page-aligned shared tokens of block 2 via a partial-block
    # radix hit (CoW copy of the sibling page + recompute from token 20)
    assert st["hits"] == 2 and st["cached_tokens"] == 40
    assert st["full_block_tokens"] == 32
    assert st["partial_tokens"] == 8 and st["partial_hits"] == 2
    assert off.prefix_stats["lookups"] == 0
    assert off.prefix_stats["hits"] == 0


def test_fully_cached_prompt_uses_cow(lm):
    cfg, params = lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 256, 24).astype(np.int32)   # page-aligned
    on, off = _engines_on_off(cfg, params, n_new=5)
    got_on = _run_sequential(on, [{"tokens": prompt}] * 2)
    got_off = _run_sequential(off, [{"tokens": prompt}] * 2)
    assert got_on == got_off == {0: got_off[0], 1: got_off[0]}
    # an identical page-aligned prompt reuses all but the last token via a
    # private copy-on-write page (a full hit would skip the logits)
    assert on.prefix_stats["cached_tokens"] == 23
    assert on.prefix_stats["computed_tokens"] == 24 + 1
    # split: 2 whole reused pages + 7 CoW-served tokens of the final page
    assert on.prefix_stats["full_block_tokens"] == 16
    assert on.prefix_stats["partial_tokens"] == 7


def test_embed_fed_stage_prefix_hits(lm):
    """Stages fed hidden states (no token ids) hash prompt-embed bytes."""
    cfg, params = lm
    emb = np.asarray(params["embed"], np.float32)
    shared = emb[np.arange(16)]
    p1 = np.concatenate([shared, emb[np.arange(20, 23)]])
    p2 = np.concatenate([shared, emb[np.arange(40, 45)]])
    on, off = _engines_on_off(cfg, params, n_new=4)
    got_on = _run_sequential(on, [{"prompt_embeds": p1},
                                  {"prompt_embeds": p2}])
    got_off = _run_sequential(off, [{"prompt_embeds": p1},
                                    {"prompt_embeds": p2}])
    assert got_on == got_off
    st = on.prefix_stats
    assert st["hits"] == 1 and st["cached_tokens"] == 16


def test_multi_turn_context_reuse(lm):
    """A follow-up whose prompt extends turn 1's full context (prompt +
    generated tokens) hits pages published at release, past the original
    prompt boundary — the block-hash chain is extended over generations."""
    cfg, params = lm
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, 256, 16).astype(np.int32)
    n_new = 8
    on, off = _engines_on_off(cfg, params, n_new=n_new)
    g1 = _run_sequential(on, [{"tokens": p1}])[0]
    # turn 2: full turn-1 context + a new user turn
    p2 = np.concatenate([p1, np.asarray(g1, np.int32),
                         rng.integers(0, 256, 5).astype(np.int32)])
    got_on = _run_sequential(on, [{"tokens": p2}])
    _run_sequential(off, [{"tokens": p1}])
    got_off = _run_sequential(off, [{"tokens": p2}])
    assert got_on == got_off
    # turn-1 KV-complete pages: prompt 16 + 7 written generated tokens
    # -> 2 full pages (16 tokens) of the 24-token turn-2 prefix
    st = on.prefix_stats
    assert st["hits"] >= 1 and st["cached_tokens"] >= 16


def test_ssm_engine_rejects_prefix_cache_and_masks_inactive_slots():
    """Recurrent-state stages have no pages to share: the engine must turn
    the flag off.  And a decode step must not advance the state of slots
    that are not decoding (a request prefilled in the same step would have
    its fresh state corrupted by the padding row)."""
    cfg = get_config("falcon_mamba_7b", smoke=True).replace(dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(4))
    sp = SamplingParams(max_new_tokens=8, temperature=0.0)
    eng = _engine(cfg, params, enable_prefix_cache=True,
                  default_sampling=sp)
    assert not eng.enable_prefix_cache
    pa = np.arange(8, dtype=np.int32)
    pb = np.arange(3, 12, dtype=np.int32)
    # stagger: A decodes while B prefills/joins mid-flight
    eng.enqueue(0, {"tokens": pa}, SamplingParams(), {})
    for _ in range(3):
        eng.step()
    eng.enqueue(1, {"tokens": pb}, SamplingParams(), {})
    results = {}
    for _ in range(200):
        for ev in eng.step():
            if ev.kind == "finished":
                results[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    assert results[0] == _greedy_reference(cfg, params, pa, 8)
    assert results[1] == _greedy_reference(cfg, params, pb, 8)


def test_warm_seeded_engine_hits_and_matches(lm):
    """Warm replica scale-up, engine level: a fresh engine seeded from a
    sibling's ``prefix_snapshot`` answers an affinity probe before its
    first request, hits the seeded pages (full + partial blocks), and its
    greedy output is byte-identical to the donor's — the injected KV is
    exactly what a local prefill would have computed."""
    cfg, params = lm
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 256, 21).astype(np.int32)   # non-aligned
    sp = SamplingParams(max_new_tokens=6, temperature=0.0)
    donor = _engine(cfg, params, enable_prefix_cache=True,
                    default_sampling=sp)
    want = _run_sequential(donor, [{"tokens": prompt}])[0]
    snap = donor.prefix_snapshot()
    assert snap and donor.scheduler.allocator.check_invariant()
    fresh = _engine(cfg, params, enable_prefix_cache=True,
                    default_sampling=sp)
    assert fresh.seed_prefixes(snap) > 0
    assert fresh.scheduler.allocator.check_invariant()
    # the affinity probe scores the seeded prefix before any request ran
    assert fresh.prefix_hint(fresh.affinity_hints({"tokens": prompt})) > 0
    got = _run_sequential(fresh, [{"tokens": prompt}])[0]
    assert got == want
    st = fresh.prefix_stats
    assert st["hits"] == 1 and st["cached_tokens"] >= 16
    assert fresh.scheduler.allocator.check_invariant()


def test_preempted_request_reacquires_published_prefix(lm):
    """Preemption + prefix cache together: the victim's pages are
    published at eviction, its re-admission re-acquires them (cached
    tokens instead of a full re-prefill), and greedy outputs still match
    the unpressured reference exactly."""
    cfg, params = lm
    rng = np.random.default_rng(7)
    # pool fits the prompts but not their decode growth -> churn
    kv = PagedKVConfig(num_pages=12, page_size=8, max_pages_per_seq=12)
    n_new = 16
    eng = AREngine("pre", cfg, params, kv=kv, max_batch=3,
                   enable_prefix_cache=True,
                   default_sampling=SamplingParams(max_new_tokens=n_new,
                                                   temperature=0.0))
    eng.scheduler.enable_preemption = True
    prompts = [rng.integers(0, 256, size=40).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
    results = {}
    for _ in range(2000):
        for ev in eng.step():
            if ev.kind == "finished":
                results[ev.req_id] = list(ev.payload["tokens"])
        assert eng.scheduler.allocator.check_invariant()
        if not eng.has_work:
            break
    assert len(results) == 3
    assert eng.scheduler.preemptions >= 1, "test must exercise preemption"
    # at least one re-admission hit the victim's own published pages
    st = eng.prefix_stats
    assert st["hits"] >= 1 and st["cached_tokens"] > 0
    for i, p in enumerate(prompts):
        want = _greedy_reference(cfg, params, p, n_new)
        assert results[i] == want, (i, results[i], want)
