"""Sharding-spec properties (these run on 1 device: specs are pure data)."""
import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.sharding import specs as S


class FakeMesh:
    """Stands in for a 16x16 mesh without touching jax devices."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class FakePodMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


MESH = FakeMesh()


@given(st.lists(st.integers(1, 64), min_size=1, max_size=4),
       st.integers(0, 3))
@settings(max_examples=100, deadline=None)
def test_fit_spec_always_legal(dims, which):
    base = [None, "model", "data", ("data", "model")][which]
    spec = P(base, *([None] * (len(dims) - 1)))
    fitted = S.fit_spec(MESH, tuple(d * 16 for d in dims), spec)
    for dim, p in zip(tuple(d * 16 for d in dims), tuple(fitted)):
        if p is not None:
            assert (dim % S.axis_size(MESH, p)) == 0


def test_fit_spec_relocates_model_axis():
    # 8 kv heads can't take model=16; the axis moves to the largest
    # divisible dim (d_model here), keeping the weight tensor-parallel
    fitted = S.fit_spec(MESH, (24, 2048, 8, 128), P(None, None, "model", None))
    assert "model" in tuple(fitted)
    assert tuple(fitted)[2] is None
    idx = tuple(fitted).index("model")
    assert (24, 2048, 8, 128)[idx] % 16 == 0


def test_fit_spec_drops_when_nothing_fits():
    fitted = S.fit_spec(MESH, (3, 5), P("model", "data"))
    assert tuple(fitted) == (None, None)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_all_leaves(arch):
    cfg = get_config(arch)   # FULL config: real production shapes
    tpl = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = S.param_specs(cfg, tpl, MESH)
    leaves_t = jax.tree.leaves(tpl)
    leaves_s = jax.tree.leaves(pspecs,
                               is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_t) == len(leaves_s)
    n_sharded = 0
    for t, s in zip(leaves_t, leaves_s):
        for dim, p in zip(t.shape, tuple(s)):
            if p is not None:
                assert dim % S.axis_size(MESH, p) == 0, (arch, t.shape, s)
                n_sharded += 1
    # the big weights must actually be sharded
    assert n_sharded >= len(leaves_t) // 3, (arch, n_sharded, len(leaves_t))


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "falcon_mamba_7b",
                                  "zamba2_2_7b"])
@pytest.mark.parametrize("batch", [128, 1])
def test_kv_cache_specs_legal(arch, batch):
    cfg = get_config(arch)
    tpl = jax.eval_shape(lambda: T.init_decode_cache(cfg, batch, 32768))
    cspecs = S.kv_cache_specs(cfg, MESH, batch)
    for key, t in tpl.items():
        sp = S.fit_spec(MESH, t.shape, cspecs[key])
        for dim, p in zip(t.shape, tuple(sp)):
            if p is not None:
                assert dim % S.axis_size(MESH, p) == 0


def test_batch_spec_prefix():
    assert S.batch_spec(MESH, 256) == ("data",)
    assert S.batch_spec(MESH, 3) is None
    pod = FakePodMesh()
    assert S.batch_spec(pod, 256) == ("pod", "data")
    assert S.batch_spec(pod, 16) == ("pod",)   # 16 % 32 != 0 -> pod only
