"""N-gram speculative decoding: must be EXACTLY greedy-equivalent and
actually accept drafts on repetitive contexts."""
import jax
import numpy as np

from repro.configs.pipelines import tiny_lm, _kv
from repro.engine.ar_engine import AREngine, _ngram_propose
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def _run(eng, prompts, n_expected):
    out = {}
    for _ in range(1000):
        for ev in eng.step():
            if ev.kind == "finished":
                out[ev.req_id] = list(ev.payload["tokens"])
        if not eng.has_work:
            break
    assert len(out) == n_expected
    return out


def test_ngram_propose():
    ctx = [1, 2, 3, 4, 1, 2, 3, 9, 5, 1, 2]
    # trailing 2-gram (1,2) most recently seen at i=4 -> continues 3,9,5
    assert _ngram_propose(ctx, 2, 3) == [3, 9, 5]
    assert _ngram_propose([7, 8], 2, 3) == []     # no earlier occurrence
    assert _ngram_propose([1], 2, 3) == []        # too short


def test_spec_decode_exactly_matches_greedy():
    cfg = tiny_lm("spec", vocab=64)   # small vocab => repetitive outputs
    params = T.init_params(cfg, jax.random.PRNGKey(8))
    rng = np.random.default_rng(0)
    # repetitive prompts encourage n-gram hits
    base = rng.integers(0, 64, size=8)
    prompts = [np.tile(base, 3).astype(np.int32),
               rng.integers(0, 64, size=20).astype(np.int32)]
    n_new = 16

    def build(spec):
        return AREngine("s", cfg, params, kv=_kv(4), max_batch=4,
                        spec_ngram=(2, 4) if spec else None,
                        default_sampling=SamplingParams(
                            max_new_tokens=n_new, temperature=0.0))

    plain = build(False)
    for i, p in enumerate(prompts):
        plain.enqueue(i, {"tokens": p}, SamplingParams(), {})
    want = _run(plain, prompts, 2)

    spec = build(True)
    for i, p in enumerate(prompts):
        spec.enqueue(i, {"tokens": p}, SamplingParams(), {})
    got = _run(spec, prompts, 2)

    for i in range(2):
        assert got[i] == want[i], (i, got[i], want[i])
    # the machinery must actually have run and accepted something
    assert spec.spec_stats["steps"] > 0
    assert spec.spec_stats["accepted"] >= 0
    assert spec.steps <= plain.steps, "spec decode must not add steps"


def test_spec_decode_accepts_on_repetitive_model():
    """A model decoding a cyclic pattern should accept many drafts."""
    cfg = tiny_lm("spec2", vocab=16)  # tiny vocab => model loops quickly
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    prompt = np.tile(np.arange(4), 6).astype(np.int32)
    eng = AREngine("s2", cfg, params, kv=_kv(2), max_batch=2,
                   spec_ngram=(2, 4),
                   default_sampling=SamplingParams(max_new_tokens=24,
                                                   temperature=0.0))
    eng.enqueue(0, {"tokens": prompt}, SamplingParams(), {})
    out = _run(eng, [prompt], 1)
    assert len(out[0]) == 24
    assert eng.spec_stats["proposed"] > 0
