"""RadixIndex unit tests + the differential property: on any trace of
publish/lookup/acquire/free operations the radix index returns exactly the
flat map's full-block hits — partial-block hits only ever ADD matched
tokens on top.  Pure python (no jax), fast tier."""
import numpy as np
import pytest

from repro.engine.kv_cache import (PageAllocator, hash_token_blocks,
                                   token_prefix_keys)
from repro.engine.radix_index import FlatIndex, RadixIndex, make_index

PAGE = 4


def _chain(tokens):
    toks = np.asarray(tokens, np.int64)
    return (hash_token_blocks(toks, PAGE), token_prefix_keys(toks, PAGE))


def _shared_prefix_seqs(rng, n=12, base_len=24):
    """Token sequences with heavy shared prefixes and non-aligned cuts."""
    base = rng.integers(0, 50, size=base_len).astype(np.int64)
    out = []
    for _ in range(n):
        cut = int(rng.integers(0, base_len + 1))
        ext = rng.integers(0, 50, size=int(rng.integers(1, 20)))
        out.append(np.concatenate([base[:cut], ext.astype(np.int64)]))
    return out


# ---------------------------------------------------------------------------
# RadixIndex units
# ---------------------------------------------------------------------------

def test_insert_lookup_roundtrip_and_prefix_walk():
    idx = RadixIndex()
    toks = np.arange(12)                       # 3 full blocks
    hashes, keys = _chain(toks)
    assert idx.insert(hashes, [7, 8, 9], keys) == 3
    assert idx.lookup(hashes) == [7, 8, 9]
    assert idx.lookup(hashes[:2]) == [7, 8]
    # a foreign chain shares nothing with the root
    other, _ = _chain(np.arange(100, 112))
    assert idx.lookup(other) == []
    # re-insert is idempotent (first writer wins, duplicate pages ignored)
    assert idx.insert(hashes, [1, 2, 3], keys) == 0
    assert idx.lookup(hashes) == [7, 8, 9]
    assert idx.check() and len(idx) == 3


def test_partial_hit_at_diverging_block():
    idx = RadixIndex()
    a = np.arange(8)                           # blocks [0..3], [4..7]
    ha, ka = _chain(a)
    idx.insert(ha, [0, 1], ka)
    # b shares block 0 and the first 2 tokens of block 1, then diverges
    b = np.array([0, 1, 2, 3, 4, 5, 99, 98])
    hb, kb = _chain(b)
    full, partial = idx.match(hb, kb)
    assert full == [0]
    assert partial == (1, 2), "first 2 tokens of the sibling page match"
    # hint scores full blocks in tokens plus the partial tail
    assert idx.hint(hb, kb, PAGE) == PAGE + 2
    # a fully diverging block yields no partial
    c = np.array([0, 1, 2, 3, 90, 91, 92, 93])
    hc, kc = _chain(c)
    full, partial = idx.match(hc, kc)
    assert full == [0] and partial is None


def test_partial_prefers_longest_match_then_smallest_page():
    idx = RadixIndex()
    shared = np.array([0, 1, 2, 3])
    for page, tail in ((5, [10, 11, 12, 13]), (3, [10, 11, 70, 71]),
                      (9, [10, 11, 12, 60])):
        h, k = _chain(np.concatenate([shared, tail]))
        idx.insert(h, [0, page], k)
    # request matches 3 leading tokens of two children (pages 5 and 9):
    # the tie breaks to the smallest page id, not insertion order
    req = np.concatenate([shared, [10, 11, 12, 99]])
    hr, kr = _chain(req)
    assert idx.match(hr, kr) == ([0], (5, 3))


def test_leaf_ordered_eviction_peels_bottom_up():
    idx = RadixIndex()
    hashes, keys = _chain(np.arange(12))
    idx.insert(hashes, [0, 1, 2], keys)
    lru = [0, 1, 2]                  # parent is coldest, but not a leaf
    assert idx.pick_evictable(lru) == 2
    idx.remove(2)
    assert idx.pick_evictable(lru[:2]) == 1
    idx.remove(1)
    assert idx.pick_evictable([0]) == 0
    idx.remove(0)
    assert len(idx) == 0 and idx.check()


def test_remove_interior_node_asserts():
    idx = RadixIndex()
    hashes, keys = _chain(np.arange(8))
    idx.insert(hashes, [0, 1], keys)
    with pytest.raises(AssertionError, match="interior"):
        idx.remove(0)


def test_paths_dedup_and_page_budget():
    idx = RadixIndex()
    shared = np.arange(8)
    a = np.concatenate([shared, [90, 91, 92, 93]])
    b = np.concatenate([shared, [80, 81, 82, 83]])
    ha, ka = _chain(a)
    hb, kb = _chain(b)
    idx.insert(ha, [0, 1, 2], ka)
    idx.insert(hb, [0, 1, 3], kb)
    paths = idx.paths()
    assert len(paths) == 2
    assert all(len(p[0]) == len(p[1]) == len(p[2]) == 3 for p in paths)
    assert {tuple(p[2]) for p in paths} == {(0, 1, 2), (0, 1, 3)}
    # deepest-first greedy truncation by DISTINCT page budget: the first
    # 3-page path covers 3 pages, so a budget of 3 keeps exactly one
    assert len(idx.paths(max_pages=3)) == 1


def test_make_index_kinds():
    assert isinstance(make_index("radix"), RadixIndex)
    assert isinstance(make_index("flat"), FlatIndex)
    with pytest.raises(ValueError, match="unknown prefix index"):
        make_index("btree")


# ---------------------------------------------------------------------------
# differential property: radix == flat on full blocks, partial only adds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_differential_index_random_traces(seed):
    rng = np.random.default_rng(seed)
    radix, flat = RadixIndex(), FlatIndex()
    next_page = 0
    for seq in _shared_prefix_seqs(rng, n=10):
        hashes, keys = _chain(seq)
        pages = []
        for h in hashes:                 # same page for same hash, always
            node = radix._by_hash.get(h)
            pages.append(node.page if node else next_page)
            if node is None:
                next_page += 1
        assert radix.insert(hashes, pages, keys) == \
            flat.insert(hashes, pages, keys)
        assert radix.check() and flat.check()
    assert set(radix.pages()) == set(flat.pages())
    for probe in _shared_prefix_seqs(rng, n=20):
        hashes, keys = _chain(probe)
        full_r, partial = radix.match(hashes, keys)
        full_f, none = flat.match(hashes, keys)
        assert full_r == full_f, "full-block hits must be identical"
        assert none is None
        # partial hits only ADD tokens past the full match, never replace
        hint_f = flat.hint(hashes, keys, PAGE)
        hint_r = radix.hint(hashes, keys, PAGE)
        assert hint_f == len(full_f) * PAGE
        if partial is None:
            assert hint_r == hint_f
        else:
            page, m = partial
            assert 0 < m <= PAGE
            assert page not in full_r
            assert hint_r == hint_f + m


@pytest.mark.parametrize("seed", range(3))
def test_differential_allocator_walk(seed):
    """Random publish/lookup/acquire/free walk on two allocators (radix vs
    flat index) with a pool large enough to avoid eviction: allocation and
    hit behavior must be bit-identical on full blocks."""
    rng = np.random.default_rng(100 + seed)
    allocs = {k: PageAllocator(256, enable_prefix_cache=True, index_kind=k,
                               page_size=PAGE) for k in ("radix", "flat")}
    seqs = _shared_prefix_seqs(rng, n=16)
    held = []
    for step in range(80):
        op = rng.integers(0, 3)
        seq = seqs[int(rng.integers(0, len(seqs)))]
        hashes, keys = _chain(seq)
        if op == 0:                          # admit + publish a chain
            rid = 1000 + step
            pages = {}
            for k, a in allocs.items():
                hit = a.lookup(hashes)
                a.acquire(rid, hit)
                fresh = a.allocate(rid, len(hashes) - len(hit))
                assert fresh is not None
                pages[k] = hit + fresh
                a.publish(pages[k], hashes, keys)
            assert pages["radix"] == pages["flat"]
            held.append(rid)
        elif op == 1 and held:               # release a random holder
            rid = held.pop(int(rng.integers(0, len(held))))
            for a in allocs.values():
                a.free(rid)
        else:                                # probe
            full = {k: a.lookup(hashes) for k, a in allocs.items()}
            assert full["radix"] == full["flat"]
            hr = allocs["radix"].prefix_hint(hashes, keys)
            hf = allocs["flat"].prefix_hint(hashes, keys)
            assert hf == len(full["flat"]) * PAGE
            assert hr >= hf, "radix may only ADD partial-hit tokens"
            assert hr - hf < PAGE
        for a in allocs.values():
            assert a.check_invariant()


@pytest.mark.parametrize("seed", range(3))
def test_radix_allocator_invariants_under_eviction_pressure(seed):
    """Small pool, heavy churn: leaf-ordered eviction keeps the tree
    prefix-closed and the allocator invariant (partition, refcounts, tree
    shape subset of LRU union referenced) at every step."""
    rng = np.random.default_rng(200 + seed)
    a = PageAllocator(12, enable_prefix_cache=True, index_kind="radix",
                      page_size=PAGE)
    seqs = _shared_prefix_seqs(rng, n=8, base_len=12)
    held = []
    for step in range(120):
        op = rng.integers(0, 4)
        seq = seqs[int(rng.integers(0, len(seqs)))]
        hashes, keys = _chain(seq)
        if op <= 1:
            rid = 1000 + step
            hit = a.lookup(hashes)
            a.acquire(rid, hit)
            fresh = a.allocate(rid, len(hashes) - len(hit))
            if fresh is None:                # pool exhausted: roll back
                a.free(rid)
            else:
                a.publish(hit + fresh, hashes, keys)
                held.append(rid)
        elif op == 2 and held:
            a.free(held.pop(int(rng.integers(0, len(held)))))
        else:                                # raw allocation pressure
            rid = -1000 - step               # plain private pages
            got = a.allocate(rid, int(rng.integers(1, 4)))
            if got is not None:
                a.free(rid)
        assert a.check_invariant(), f"invariant broke at step {step}"
        # prefix closure: any indexed chain is hit contiguously from root
        full = a.lookup(hashes)
        assert len(full) <= len(hashes)
    assert a.evictions > 0, "walk must exercise eviction"
