"""Serving metrics (JCT/TTFT/throughput) over completed requests."""
import numpy as np

from repro.configs.pipelines import build_qwen_omni
from repro.core.metrics import summarize
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def test_summarize_on_real_pipeline():
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=3,
                                        talker_tokens=6, stream_chunk=3,
                                        dit_steps=2)
    orch = Orchestrator(graph, engines)
    reqs = [Request(inputs={"tokens": np.arange(6, dtype=np.int32)})
            for _ in range(3)]
    for r in reqs:
        orch.submit(r)
    orch.run()
    m = summarize(reqs, wall_time=1.0)
    assert m["n"] == 3
    assert m["jct_mean"] > 0
    assert m["jct_p95"] >= m["jct_p50"] > 0
    # streaming: first output strictly precedes completion
    assert 0 < m["ttft_p50"] <= m["jct_p50"]
    assert m["req_per_s"] == 3.0


def test_ttft_recorded_only_once():
    graph, engines, _ = build_qwen_omni(max_batch=2, thinker_tokens=3,
                                        talker_tokens=9, stream_chunk=3,
                                        dit_steps=2)
    orch = Orchestrator(graph, engines)
    req = Request(inputs={"tokens": np.arange(6, dtype=np.int32)})
    orch.submit(req)
    orch.run()
    assert req.first_output_time is not None
    assert req.first_output_time <= req.completion_time
    # 9 talker tokens / 3-chunks => 3 vocoder chunks collected
    assert len(req.outputs["vocoder"]) == 3
