"""Per-architecture smoke tests (reduced configs, CPU): one forward and one
train step with shape + finiteness assertions, plus decode==full cache
consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


def _inputs(cfg, key, B=2, S=16):
    if cfg.modality == "audio_frames":
        x = jax.random.normal(key, (B, S, cfg.d_model),
                              dtype=jnp.dtype(cfg.dtype))
    else:
        x = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return x, labels


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, rng)
    x, _ = _inputs(cfg, rng)
    logits, aux = T.forward_full(cfg, params, x)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, rng)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=1,
                                                    total_steps=10)))
    x, labels = _inputs(cfg, rng)
    params2, opt2, metrics = step(params, opt, x, labels)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(opt2["step"]) == 1
    # params actually moved
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in
               zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert diff > 0


@pytest.mark.parametrize("arch", ["qwen2_5_14b", "starcoder2_7b",
                                  "mixtral_8x7b", "qwen3_moe_30b_a3b",
                                  "falcon_mamba_7b", "zamba2_2_7b",
                                  "chameleon_34b", "qwen1_5_4b",
                                  "internlm2_1_8b"])
def test_decode_matches_full(arch, rng):
    cfg = get_config(arch, smoke=True).replace(dtype="float32",
                                               capacity_factor=1e9)
    params = T.init_params(cfg, rng)
    B, S = 2, 12
    toks = jax.random.randint(rng, (B, S + 3), 0, cfg.vocab_size)
    full, _ = T.forward_full(cfg, params, toks, remat=False)
    lo, cache = T.forward_prefill(cfg, params, toks[:, :S], max_seq=S + 8,
                                  remat=False)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(full[:, :S]),
                               rtol=2e-4, atol=2e-4)
    for i in range(3):
        lo, cache = T.forward_decode(cfg, params, cache,
                                     toks[:, S + i:S + i + 1],
                                     jnp.full((B,), S + i))
        np.testing.assert_allclose(np.asarray(lo[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   rtol=2e-3, atol=2e-3)


def test_swa_variant_differs(rng):
    """Sliding-window attention must change long-range attention results."""
    cfg = get_config("qwen2_5_14b", smoke=True).replace(dtype="float32")
    params = T.init_params(cfg, rng)
    toks = jax.random.randint(rng, (1, 64), 0, cfg.vocab_size)
    full, _ = T.forward_full(cfg, params, toks)
    swa, _ = T.forward_full(cfg.replace(attn_variant="swa",
                                        sliding_window=8), params, toks)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(swa[:, -1]))
    # early tokens (inside the window) agree
    np.testing.assert_allclose(np.asarray(full[:, 4]), np.asarray(swa[:, 4]),
                               rtol=1e-4, atol=1e-4)


def test_encoder_is_bidirectional(rng):
    cfg = get_config("hubert_xlarge", smoke=True).replace(dtype="float32")
    params = T.init_params(cfg, rng)
    x = jax.random.normal(rng, (1, 16, cfg.d_model))
    base, _ = T.forward_full(cfg, params, x)
    x2 = x.at[:, -1].set(0.0)   # perturb the LAST frame
    pert, _ = T.forward_full(cfg, params, x2)
    # bidirectional: the FIRST position must see the change
    assert not np.allclose(np.asarray(base[:, 0]), np.asarray(pert[:, 0]))
