PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python
STAMP := $(shell date -u +%Y%m%dT%H%M%SZ)
SMOKE_DUMPS := BENCH_prefix_cache.json BENCH_online.json \
    BENCH_replicas.json BENCH_radix.json

.PHONY: test test-fast lint analyze check serve-online bench-online \
    bench-smoke bench-compare bench-trend

# default pre-commit check: repo-wide lint + invariant analyzer +
# sub-minute smoke subset
check: lint analyze test-fast

lint:
	python tools/lint.py

# repo-specific invariant analyzer (lock discipline/order, blocking
# calls under locks, connector key lifetime, spawn safety, deprecated
# surfaces).  Exits non-zero on any non-baselined finding; see
# tools/analyze/__init__.py for the rule codes and the noqa/baseline
# workflow.  `make analyze JSON=findings.json` also dumps JSON.
analyze:
	python -m tools.analyze $(if $(JSON),--json $(JSON))

test-fast:
	$(PY) -m pytest -q -m fast

# full tier-1 suite (~6.5 min)
test:
	$(PY) -m pytest -q

# online serving demo through the per-stage-worker backend
serve-online:
	$(PY) -m repro.launch.serve --pipeline qwen_omni --online \
	    --requests 12 --rate 4.0 --max-inflight 8

# concurrent-stage vs lock-step comparison with a slowed stage
bench-online:
	$(PY) -m benchmarks.bench_online

# sub-minute benchmark smoke: online serving + prefix caching (flat and
# radix) + replica scaling.  Each dump is archived under
# benchmarks/history/ with a UTC timestamp so benchmarks/compare.py
# --archive can render the cross-PR trend.
bench-smoke:
	$(PY) -m benchmarks.bench_prefix_cache --smoke \
	    --json BENCH_prefix_cache.json
	$(PY) -m benchmarks.bench_online --smoke --json BENCH_online.json
	$(PY) -m benchmarks.bench_replicas --smoke --json BENCH_replicas.json
	$(PY) -m benchmarks.bench_radix --smoke --json BENCH_radix.json
	mkdir -p benchmarks/history
	for f in $(SMOKE_DUMPS); do \
	    cp $$f benchmarks/history/$(STAMP)_$$f; done
	$(PY) -m benchmarks.compare $(SMOKE_DUMPS) || true
	$(PY) -m benchmarks.compare --archive || true

# diff two or more BENCH_*.json dumps (regression table / trend):
#   make bench-compare FILES="old.json new.json"
bench-compare:
	$(PY) -m benchmarks.compare $(FILES)

# cross-run trend from everything archived under benchmarks/history/
bench-trend:
	$(PY) -m benchmarks.compare --archive
