PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test test-fast check serve-online bench-online bench-smoke \
    bench-compare

# default pre-commit check: sub-minute smoke subset
check: test-fast

test-fast:
	$(PY) -m pytest -q -m fast

# full tier-1 suite (~6.5 min)
test:
	$(PY) -m pytest -q

# online serving demo through the per-stage-worker backend
serve-online:
	$(PY) -m repro.launch.serve --pipeline qwen_omni --online \
	    --requests 12 --rate 4.0 --max-inflight 8

# concurrent-stage vs lock-step comparison with a slowed stage
bench-online:
	$(PY) -m benchmarks.bench_online

# sub-minute benchmark smoke: online serving + prefix caching + replica
# scaling, JSON out, then a cross-run trend table over the dumps
bench-smoke:
	$(PY) -m benchmarks.bench_prefix_cache --smoke \
	    --json BENCH_prefix_cache.json
	$(PY) -m benchmarks.bench_online --smoke --json BENCH_online.json
	$(PY) -m benchmarks.bench_replicas --smoke --json BENCH_replicas.json
	$(PY) -m benchmarks.compare BENCH_prefix_cache.json \
	    BENCH_online.json BENCH_replicas.json || true

# diff two or more BENCH_*.json dumps (regression table / trend):
#   make bench-compare FILES="old.json new.json"
bench-compare:
	$(PY) -m benchmarks.compare $(FILES)
