"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.orchestrator import Orchestrator
from repro.core.request import Request

FRAME_SECONDS = 0.02          # one vocoder latent frame = 20 ms of audio


class SlowedEngine:
    """Wraps a StageEngine, adding a fixed dwell to every step that has
    work — emulates a much heavier model on one stage so benchmarks can
    show what a slow stage does to the rest of the pipeline (lock-step:
    stalls everything; per-stage workers: only its own queue grows)."""

    def __init__(self, engine, step_delay_s: float):
        self.engine = engine
        self.step_delay_s = step_delay_s
        self.name = engine.name
        self._extra_busy = 0.0

    def enqueue(self, req_id, inputs, sampling, data):
        self.engine.enqueue(req_id, inputs, sampling, data)

    def step(self):
        if self.engine.has_work:
            time.sleep(self.step_delay_s)
            self._extra_busy += self.step_delay_s
        return self.engine.step()

    @property
    def has_work(self) -> bool:
        return self.engine.has_work

    @property
    def queue_depth(self) -> int:
        return getattr(self.engine, "queue_depth", 0)

    @property
    def busy_time(self) -> float:
        return self.engine.busy_time + self._extra_busy


def prompts(n: int, lo=8, hi=24, vocab=500, seed=0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=int(rng.integers(lo, hi))
                         ).astype(np.int32) for _ in range(n)]


def run_batch(orch: Orchestrator, inputs_list) -> List[Request]:
    """Submit a batch at t=0 and run to completion (offline inference)."""
    reqs = [Request(inputs=i) for i in inputs_list]
    for r in reqs:
        orch.submit(r)
    orch.run()
    return reqs


def warmup(orch: Orchestrator, inputs_list) -> None:
    run_batch(orch, inputs_list)


def audio_seconds(n_frames: int) -> float:
    return n_frames * FRAME_SECONDS


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
