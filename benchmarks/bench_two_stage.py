"""BAGEL / MiMo-Audio reproduction (§4.2): two-stage AR+generator pipelines,
staged serving vs sequential baseline."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import audio_seconds, prompts, run_batch, warmup
from repro.configs.pipelines import build_ar_dit, build_mimo_audio
from repro.core.orchestrator import Orchestrator


def run(n_requests: int = 6, seed: int = 0) -> list:
    rows = []
    # ---- BAGEL-style (AR understanding -> DiT generation) -------------
    graph, engines, _ = build_ar_dit("bagel", max_batch=4, ar_tokens=12,
                                     image_latents=32, dit_steps=4, seed=seed)
    orch = Orchestrator(graph, engines)
    warmup(orch, [{"tokens": p} for p in prompts(2, seed=55)])
    reqs = run_batch(orch, [{"tokens": p} for p in prompts(n_requests,
                                                           seed=seed)])
    jct = float(np.mean([r.jct for r in reqs]))
    # sequential baseline: same machinery, one request at a time; request i's
    # JCT accumulates the queueing delay behind requests 0..i-1 (offline
    # inference semantics, as in the paper's §4 baselines)
    graph2, engines2, _ = build_ar_dit("bagel2", max_batch=1, ar_tokens=12,
                                       image_latents=32, dit_steps=4,
                                       seed=seed)
    orch2 = Orchestrator(graph2, engines2)
    warmup(orch2, [{"tokens": p} for p in prompts(1, seed=56)])
    t0 = time.perf_counter()
    seq_jcts = []
    for p in prompts(n_requests, seed=seed):
        run_batch(orch2, [{"tokens": p}])
        seq_jcts.append(time.perf_counter() - t0)   # cumulative completion
    jct_seq = float(np.mean(seq_jcts))
    rows.append(("bagel_t2i_jct", jct * 1e6,
                 f"staged={jct:.3f}s sequential={jct_seq:.3f}s "
                 f"jct_reduction={100*(1-jct/jct_seq):.1f}%"))

    # ---- MiMo-Audio (patch enc -> AR -> patch dec), RTF ----------------
    graph3, engines3, _ = build_mimo_audio(max_batch=4, ar_tokens=24,
                                           seed=seed)
    orch3 = Orchestrator(graph3, engines3)
    rng = np.random.default_rng(seed)
    mk = lambda: {"audio": rng.standard_normal((32, 16)).astype(np.float32)}
    warmup(orch3, [mk() for _ in range(2)])
    reqs = run_batch(orch3, [mk() for _ in range(n_requests)])
    jct3 = float(np.mean([r.jct for r in reqs]))
    # generated audio: ar_tokens patches * patch(4) frames
    rtf = jct3 / audio_seconds(24 * 4)
    rows.append(("mimo_audio_rtf", rtf * 1e6, f"rtf={rtf:.3f} jct={jct3:.3f}s"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
