"""Figure 6 reproduction: end-to-end Qwen-Omni serving.

Disaggregated stage-graph serving (this work) vs the monolithic sequential
baseline (HF-Transformers style), same tiny weights: JCT, RTF, and
per-stage TPS — the paper's metrics (§4.1).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import audio_seconds, prompts, run_batch, warmup
from repro.baselines.monolithic import MonolithicQwenOmni
from repro.configs.pipelines import build_qwen_omni
from repro.core.metrics import summarize_queueing
from repro.core.orchestrator import Orchestrator
from repro.models.dit import DiTConfig, init_dit
import jax


def run(n_requests: int = 8, thinker_tokens: int = 10, talker_tokens: int = 40,
        dit_steps: int = 4, seed: int = 0) -> list:
    rows = []
    # ---- disaggregated (vLLM-Omni) -----------------------------------
    graph, engines, bundle = build_qwen_omni(
        max_batch=4, thinker_tokens=thinker_tokens,
        talker_tokens=talker_tokens, stream_chunk=8, dit_steps=dit_steps,
        seed=seed)
    orch = Orchestrator(graph, engines)
    warmup(orch, [{"tokens": p} for p in prompts(2, seed=99)])
    t0 = time.perf_counter()
    reqs = run_batch(orch, [{"tokens": p} for p in prompts(n_requests,
                                                           seed=seed)])
    wall_dis = time.perf_counter() - t0
    jct_dis = float(np.mean([r.jct for r in reqs]))
    # per-stage queueing delay through the per-stage-worker backend
    qd = summarize_queueing(reqs)
    frames = talker_tokens * 2
    rtf_dis = jct_dis / audio_seconds(frames)
    thinker_busy = engines["thinker"].busy_time
    talker_busy = engines["talker"].busy_time
    tps_thinker_dis = n_requests * thinker_tokens / max(1e-9, thinker_busy)
    tps_talker_dis = n_requests * talker_tokens / max(1e-9, talker_busy)

    # ---- monolithic baseline ------------------------------------------
    vcfg = DiTConfig(name="vocoder", num_layers=2, d_model=128, num_heads=4,
                     d_ff=256, in_dim=32, cond_dim=128, num_steps=dit_steps)
    vparams = init_dit(vcfg, jax.random.PRNGKey(seed + 7))
    mono = MonolithicQwenOmni(bundle, (vcfg, vparams), dit_steps=dit_steps,
                              seed=seed)
    mono.run(prompts(1, seed=98))            # warm the jit caches
    res = mono.run(prompts(n_requests, seed=seed))
    jct_mono = float(np.mean([r["jct"] for r in res]))
    rtf_mono = jct_mono / audio_seconds(frames)
    thinker_t = sum(r["thinker_time"] for r in res)
    talker_t = sum(r["talker_time"] for r in res)
    tps_thinker_mono = n_requests * thinker_tokens / thinker_t
    tps_talker_mono = n_requests * talker_tokens / talker_t

    jct_red = 100 * (1 - jct_dis / jct_mono)
    rows.append(("fig6_jct_monolithic_s", jct_mono * 1e6,
                 f"jct={jct_mono:.3f}s"))
    rows.append(("fig6_jct_disaggregated_s", jct_dis * 1e6,
                 f"jct={jct_dis:.3f}s reduction={jct_red:.1f}%"))
    rows.append(("fig6_rtf", rtf_dis * 1e6,
                 f"rtf_dis={rtf_dis:.3f} rtf_mono={rtf_mono:.3f} "
                 f"reduction={100*(1-rtf_dis/rtf_mono):.1f}%"))
    rows.append(("fig6_thinker_tps", 1e6 / max(tps_thinker_dis, 1e-9),
                 f"dis={tps_thinker_dis:.1f} mono={tps_thinker_mono:.1f} "
                 f"speedup={tps_thinker_dis/tps_thinker_mono:.2f}x"))
    rows.append(("fig6_talker_tps", 1e6 / max(tps_talker_dis, 1e-9),
                 f"dis={tps_talker_dis:.1f} mono={tps_talker_mono:.1f} "
                 f"speedup={tps_talker_dis/tps_talker_mono:.2f}x"))
    if qd:
        worst = max(qd.items(), key=lambda kv: kv[1]["p95"])
        rows.append(("fig6_queue_delay_p95", worst[1]["p95"] * 1e6,
                     f"worst stage={worst[0]} "
                     f"p95={worst[1]['p95']*1e3:.2f}ms"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
