"""Analytic FLOPs / HBM-traffic model per (arch, shape).

Why analytic: XLA's ``compiled.cost_analysis()`` on CPU counts while-loop
bodies ONCE (verified empirically — a scan of 8 matmuls reports the flops
of 1), so any scan-over-layers or scan-over-sequence model is undercounted
by orders of magnitude. Roofline compute/memory terms therefore come from
the standard analytic accounting below (the same math MFU reports use);
the HLO is still the source of truth for the collective term (with
loop-trip correction) and for memory_analysis bytes.

All quantities are GLOBAL (whole job); the roofline divides by chip count.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.mamba import dt_rank, n_heads2


@dataclass
class CostEstimate:
    flops: float              # executed flops (incl. remat & MoE capacity)
    model_flops: float        # "useful" flops: 6ND / 2ND with N_active
    hbm_bytes: float          # global HBM traffic per step
    notes: str = ""


def _attn_layer_flops(cfg: ModelConfig, T: float, ctx: float) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    proj = 2 * T * d * (nq + 2 * nkv) * hd + 2 * T * nq * hd * d
    attn = 2 * T * ctx * nq * hd * 2          # QK^T and PV
    return proj + attn


def _mlp_layer_flops(cfg: ModelConfig, T: float, capacity_overhead=1.0):
    if cfg.is_moe:
        return 6 * T * cfg.experts_per_token * cfg.d_model * cfg.d_ff \
            * capacity_overhead + 2 * T * cfg.d_model * cfg.num_experts
    return 6 * T * cfg.d_model * cfg.d_ff


def _mamba_layer_flops(cfg: ModelConfig, T: float) -> float:
    d, di, n, cw = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    if cfg.ssm_version == 1:
        r = dt_rank(cfg)
        return (2 * T * d * 2 * di + 2 * T * cw * di
                + 2 * T * di * (r + 2 * n) + 2 * T * r * di
                + T * di * n * 6                 # dA, h update, y contraction
                + 2 * T * di * d)
    nh = n_heads2(cfg)
    return (2 * T * d * (2 * di + 2 * n + nh) + 2 * T * cw * (di + 2 * n)
            + T * di * n * 6 + 2 * T * di * d)


def _ctx(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Average attention context per query token."""
    S = shape.seq_len
    window = cfg.sliding_window if cfg.attn_variant == "swa" else 0
    if shape.kind == "decode":
        ctx = S
    elif cfg.is_encoder:
        ctx = S
    else:
        ctx = S / 2                             # causal average
    if window:
        ctx = min(ctx, window)
    return ctx


def estimate(cfg: ModelConfig, shape: ShapeConfig) -> CostEstimate:
    B, S = shape.global_batch, shape.seq_len
    T = B * (1 if shape.kind == "decode" else S)  # tokens processed
    ctx = _ctx(cfg, shape)
    cap = cfg.capacity_factor if cfg.is_moe else 1.0

    per_layer = 0.0
    layers_attn = 0
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        per_layer = _attn_layer_flops(cfg, T, ctx) + _mlp_layer_flops(cfg, T, cap)
        fwd = cfg.num_layers * per_layer
    elif cfg.arch_type == "ssm":
        fwd = cfg.num_layers * _mamba_layer_flops(cfg, T)
    else:  # hybrid
        sites = cfg.num_layers // cfg.shared_attn_every
        fwd = (cfg.num_layers * _mamba_layer_flops(cfg, T)
               + sites * (_attn_layer_flops(cfg, T, ctx)
                          + _mlp_layer_flops(cfg, T)))
    # embedding + head
    fwd += 2 * T * cfg.d_model * cfg.vocab_size
    if cfg.modality != "audio_frames":
        fwd += 0  # embed lookup is a gather, ~0 flops

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        flops = 4.0 * fwd            # fwd + 2x bwd + full-remat recompute
        model = 6.0 * n_active * T
    elif shape.kind == "prefill":
        flops = fwd
        model = 2.0 * n_active * T
    else:
        flops = fwd
        model = 2.0 * n_active * T

    # ---- HBM traffic (coarse, documented) ------------------------------
    pbytes = 2.0 * n_params                      # bf16 weights read once
    act = 2.0 * T * cfg.d_model * 12             # ~12 intermediate tensors/layer-agnostic
    act *= max(1, cfg.num_layers // 8)           # activation reuse factor
    cache = 0.0
    if shape.kind == "decode" and cfg.arch_type not in ("ssm",):
        kvh = cfg.num_kv_heads
        eff_ctx = ctx
        # bytes/elem: 2 (bf16) or 1 + scales overhead (int8-quantized KV)
        kv_b = (1.0 + 4.0 / cfg.head_dim) if cfg.kv_cache_dtype == "int8" \
            else 2.0
        cache = (cfg.num_layers if cfg.arch_type != "hybrid"
                 else cfg.num_layers // cfg.shared_attn_every) \
            * B * eff_ctx * kvh * cfg.head_dim * 2 * kv_b
    if shape.kind == "decode" and cfg.arch_type in ("ssm", "hybrid"):
        cache += cfg.num_layers * B * cfg.d_inner * max(1, cfg.ssm_state) * 4
    if shape.kind == "train":
        hbm = 10.0 * 2 * n_params + 3 * act      # params+grads+opt + acts
    else:
        hbm = pbytes + act + cache
    return CostEstimate(flops=flops, model_flops=model, hbm_bytes=hbm)
