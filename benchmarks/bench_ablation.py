"""Ablations over the serving optimizations the stage engines inherit
(paper §2.2/§3.3): continuous-batching degree and chunked prefill.

  - batching sweep: throughput of one AR stage at max_batch 1/2/4/8;
  - chunked prefill: short-request JCT when a long prompt shares the
    engine, with small chunks (decodes interleave) vs monolithic prefill.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.pipelines import tiny_lm, _kv
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def _drain(eng, n_expected):
    done = {}
    t0 = time.perf_counter()
    for _ in range(100_000):
        for ev in eng.step():
            if ev.kind == "finished":
                done[ev.req_id] = time.perf_counter() - t0
        if not eng.has_work:
            break
    return done


def run(n_requests: int = 12, max_new: int = 16, seed: int = 0) -> list:
    cfg = tiny_lm("abl", vocab=256)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 256, size=12).astype(np.int32)
               for _ in range(n_requests)]
    rows = []

    # ---- continuous-batching degree ------------------------------------
    base_tps = None
    for mb in (1, 2, 4, 8):
        eng = AREngine("abl", cfg, params, kv=_kv(mb), max_batch=mb,
                       default_sampling=SamplingParams(
                           max_new_tokens=max_new, temperature=0.0))
        # warm
        eng.enqueue(-1, {"tokens": prompts[0]}, SamplingParams(), {})
        _drain(eng, 1)
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
        _drain(eng, n_requests)
        wall = time.perf_counter() - t0
        tps = n_requests * max_new / wall
        base_tps = base_tps or tps
        rows.append((f"ablation_batch{mb}_tps", 1e6 / tps,
                     f"tokens/s={tps:.1f} vs_mb1={tps/base_tps:.2f}x"))

    # ---- chunked prefill -------------------------------------------------
    long_prompt = rng.integers(0, 256, size=192).astype(np.int32)
    res = {}
    for label, chunk, budget in (("chunked", 32, 40),
                                 ("monolithic", 192, 256)):
        eng = AREngine("abl2", cfg, params, kv=_kv(4, max_seq=256),
                       max_batch=4, token_budget=budget, chunk_size=chunk,
                       default_sampling=SamplingParams(
                           max_new_tokens=max_new, temperature=0.0))
        eng.enqueue(-1, {"tokens": prompts[0]}, SamplingParams(), {})
        _drain(eng, 1)
        # short request is already decoding when the long prompt arrives
        eng.enqueue(100, {"tokens": prompts[0]}, SamplingParams(), {})
        eng.step()
        eng.enqueue(101, {"tokens": long_prompt}, SamplingParams(), {})
        done = _drain(eng, 2)
        res[label] = done[100]
    rows.append(("ablation_chunked_prefill_short_jct",
                 res["chunked"] * 1e6,
                 f"chunked={res['chunked']*1e3:.1f}ms "
                 f"monolithic={res['monolithic']*1e3:.1f}ms "
                 f"(CPU prefill is ~ms-fast so stall protection is not "
                 f"visible here; the mechanism is exercised functionally — "
                 f"decodes interleave with prefill chunks under one token "
                 f"budget, scheduler-tested)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
