"""Streaming stage output (§3.3): TTFT of the FINAL (vocoder) output with
streaming Talker->Vocoder vs waiting for the full codec sequence."""
from __future__ import annotations

import time


from benchmarks.common import prompts, warmup
from repro.configs.pipelines import build_qwen_omni
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def _first_output_latency(stream_chunk: int, seed: int = 0) -> float:
    graph, engines, _ = build_qwen_omni(
        max_batch=2, thinker_tokens=6, talker_tokens=48,
        stream_chunk=stream_chunk, dit_steps=2, seed=seed)
    orch = Orchestrator(graph, engines)
    warmup(orch, [{"tokens": p} for p in prompts(1, seed=44)])
    req = Request(inputs={"tokens": prompts(1, seed=seed)[0]})
    t0 = time.perf_counter()
    orch.submit(req)
    ttft = None
    for _ in range(20000):
        busy = any(engines[n].has_work for n in graph.stages)
        orch.tick()
        if ttft is None and req.outputs.get("vocoder"):
            ttft = time.perf_counter() - t0
        if req.completion_time is not None:
            break
        if not busy:
            break
    return ttft if ttft is not None else float("nan")


def run(seed: int = 0) -> list:
    ttft_stream = _first_output_latency(stream_chunk=8, seed=seed)
    ttft_wait = _first_output_latency(stream_chunk=0, seed=seed)
    return [("streaming_ttft", ttft_stream * 1e6,
             f"stream={ttft_stream:.3f}s nonstream={ttft_wait:.3f}s "
             f"reduction={100*(1-ttft_stream/ttft_wait):.1f}%")]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
