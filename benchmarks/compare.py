"""Diff benchmark JSON files across runs (regression tracking).

Each input is a ``--json`` dump from benchmarks/run.py or any single
bench module: a list of ``{name, us_per_call, derived}`` records.  With
two files the output is a baseline-vs-candidate regression table; with
three or more, a trend table (one column per file, oldest first), so the
bench-smoke tier can track a metric's trajectory across PRs.

Lower is better for every row (``us_per_call`` is a latency-like
number); rows whose name ends in ``_rate`` / ``_per_s`` / ``equality``
are higher-is-better and the regression sign flips accordingly.

  PYTHONPATH=src python -m benchmarks.compare BENCH_a.json BENCH_b.json \
      [BENCH_c.json ...] [--threshold 10] [--fail-on-regression]

``--archive`` mode instead scans ``benchmarks/history/`` (where ``make
bench-smoke`` drops a ``<UTC-stamp>_BENCH_<bench>.json`` copy of every
dump) and renders one trend table per bench, oldest run first — the
cross-PR trajectory of each metric:

  PYTHONPATH=src python -m benchmarks.compare --archive [--last 6]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List

HISTORY_DIR = os.path.join(os.path.dirname(__file__), "history")
_ARCHIVE_RE = re.compile(r"^(?P<stamp>[0-9TZ]+)_(?P<bench>BENCH_.+)\.json$")


HIGHER_IS_BETTER = ("_rate", "_per_s", "equality", "speedup")


def load(path: str) -> Dict[str, float]:
    with open(path) as f:
        recs = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in recs
            if not r["name"].endswith("_harness_wall")}


def higher_is_better(name: str) -> bool:
    return any(name.endswith(s) or s in name for s in HIGHER_IS_BETTER)


def pct_change(old: float, new: float) -> float:
    if old == 0:
        return 0.0 if new == 0 else float("inf")
    return 100.0 * (new - old) / old


def regression(name: str, old: float, new: float) -> float:
    """Signed regression percentage: positive = got worse."""
    d = pct_change(old, new)
    return -d if higher_is_better(name) else d


def compare(paths: List[str], threshold: float) -> int:
    return table([(os.path.basename(p), load(p)) for p in paths], threshold)


def table(runs: List[tuple], threshold: float) -> int:
    names: List[str] = []
    for _, rows in runs:                 # first-seen order, union
        for n in rows:
            if n not in names:
                names.append(n)

    w = max((len(n) for n in names), default=4) + 2
    cols = [label[:16] for label, _ in runs]
    print("metric".ljust(w) + "".join(c.rjust(18) for c in cols)
          + ("   change" if len(runs) == 2 else "   trend"))
    regressions = 0
    for n in names:
        vals = [rows.get(n) for _, rows in runs]
        cells = "".join((f"{v:.1f}" if v is not None else "-").rjust(18)
                        for v in vals)
        present = [v for v in vals if v is not None]
        tail = ""
        if len(present) >= 2 and present[0] is not None:
            reg = regression(n, present[0], present[-1])
            arrow = "" if abs(reg) < threshold else (
                "  << REGRESSION" if reg > 0 else "  improved")
            sign = "+" if reg > 0 else ""
            tail = f"   {sign}{reg:.1f}%{arrow}"
            if reg > threshold:
                regressions += 1
        print(n.ljust(w) + cells + tail)
    if regressions:
        print(f"\n{regressions} metric(s) regressed more than "
              f"{threshold:.0f}% vs {runs[0][0]}")
    return regressions


def archive_trend(history_dir: str, threshold: float, last: int) -> int:
    """One trend table per bench over the archived bench-smoke dumps."""
    groups: Dict[str, List[tuple]] = {}
    try:
        entries = sorted(os.listdir(history_dir))
    except FileNotFoundError:
        entries = []
    for fname in entries:                # sorted => chronological stamps
        m = _ARCHIVE_RE.match(fname)
        if m:
            groups.setdefault(m.group("bench"), []).append(
                (m.group("stamp"), os.path.join(history_dir, fname)))
    if not groups:
        print(f"no archived runs under {history_dir} "
              "(run `make bench-smoke` to populate it)")
        return 0
    regressions = 0
    for bench in sorted(groups):
        runs = groups[bench][-last:]
        print(f"\n== {bench} ({len(runs)} archived run(s), oldest first)")
        regressions += table([(stamp, load(path)) for stamp, path in runs],
                             threshold)
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", help="2+ BENCH_*.json files, "
                    "oldest (baseline) first")
    ap.add_argument("--archive", action="store_true",
                    help="render per-bench trends from benchmarks/history/ "
                    "instead of comparing explicit files")
    ap.add_argument("--history-dir", default=HISTORY_DIR,
                    help="archive directory for --archive mode")
    ap.add_argument("--last", type=int, default=8,
                    help="--archive: show at most the last N runs per bench")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="regression flag threshold in percent")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 if any metric regressed past threshold")
    args = ap.parse_args()
    if args.archive:
        if args.files:
            ap.error("--archive takes no positional files")
        n = archive_trend(args.history_dir, args.threshold, args.last)
    else:
        if len(args.files) < 2:
            ap.error("need at least two files to compare "
                     "(or use --archive)")
        n = compare(args.files, args.threshold)
    if args.fail_on_regression and n:
        sys.exit(1)


if __name__ == "__main__":
    main()
