"""Diff benchmark JSON files across runs (regression tracking).

Each input is a ``--json`` dump from benchmarks/run.py or any single
bench module: a list of ``{name, us_per_call, derived}`` records.  With
two files the output is a baseline-vs-candidate regression table; with
three or more, a trend table (one column per file, oldest first), so the
bench-smoke tier can track a metric's trajectory across PRs.

Lower is better for every row (``us_per_call`` is a latency-like
number); rows whose name ends in ``_rate`` / ``_per_s`` / ``equality``
are higher-is-better and the regression sign flips accordingly.

  PYTHONPATH=src python -m benchmarks.compare BENCH_a.json BENCH_b.json \
      [BENCH_c.json ...] [--threshold 10] [--fail-on-regression]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List


HIGHER_IS_BETTER = ("_rate", "_per_s", "equality", "speedup")


def load(path: str) -> Dict[str, float]:
    with open(path) as f:
        recs = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in recs
            if not r["name"].endswith("_harness_wall")}


def higher_is_better(name: str) -> bool:
    return any(name.endswith(s) or s in name for s in HIGHER_IS_BETTER)


def pct_change(old: float, new: float) -> float:
    if old == 0:
        return 0.0 if new == 0 else float("inf")
    return 100.0 * (new - old) / old


def regression(name: str, old: float, new: float) -> float:
    """Signed regression percentage: positive = got worse."""
    d = pct_change(old, new)
    return -d if higher_is_better(name) else d


def compare(paths: List[str], threshold: float) -> int:
    runs = [(os.path.basename(p), load(p)) for p in paths]
    names: List[str] = []
    for _, rows in runs:                 # first-seen order, union
        for n in rows:
            if n not in names:
                names.append(n)

    w = max((len(n) for n in names), default=4) + 2
    cols = [label[:16] for label, _ in runs]
    print("metric".ljust(w) + "".join(c.rjust(18) for c in cols)
          + ("   change" if len(runs) == 2 else "   trend"))
    regressions = 0
    for n in names:
        vals = [rows.get(n) for _, rows in runs]
        cells = "".join((f"{v:.1f}" if v is not None else "-").rjust(18)
                        for v in vals)
        present = [v for v in vals if v is not None]
        tail = ""
        if len(present) >= 2 and present[0] is not None:
            reg = regression(n, present[0], present[-1])
            arrow = "" if abs(reg) < threshold else (
                "  << REGRESSION" if reg > 0 else "  improved")
            sign = "+" if reg > 0 else ""
            tail = f"   {sign}{reg:.1f}%{arrow}"
            if reg > threshold:
                regressions += 1
        print(n.ljust(w) + cells + tail)
    if regressions:
        print(f"\n{regressions} metric(s) regressed more than "
              f"{threshold:.0f}% vs {runs[0][0]}")
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="2+ BENCH_*.json files, "
                    "oldest (baseline) first")
    ap.add_argument("--threshold", type=float, default=10.0,
                    help="regression flag threshold in percent")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 if any metric regressed past threshold")
    args = ap.parse_args()
    if len(args.files) < 2:
        ap.error("need at least two files to compare")
    n = compare(args.files, args.threshold)
    if args.fail_on_regression and n:
        sys.exit(1)


if __name__ == "__main__":
    main()
