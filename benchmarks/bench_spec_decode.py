"""Speculative decoding (n-gram prompt-lookup): engine steps and wall time
per generated token on a repetitive workload, vs plain decode — output
greedy-identical by construction (tests/test_spec_decode.py)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.pipelines import tiny_lm, _kv
from repro.engine.ar_engine import AREngine
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def run(n_requests: int = 4, n_new: int = 32, seed: int = 0) -> list:
    cfg = tiny_lm("specb", vocab=32)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 32, size=6)
    prompts = [np.tile(base, 4).astype(np.int32) for _ in range(n_requests)]

    def measure(spec):
        eng = AREngine("b", cfg, params, kv=_kv(4), max_batch=4,
                       spec_ngram=(2, 6) if spec else None,
                       default_sampling=SamplingParams(
                           max_new_tokens=n_new, temperature=0.0))
        # warm
        eng.enqueue(-1, {"tokens": prompts[0]}, SamplingParams(), {})
        while eng.has_work:
            eng.step()
        eng.steps = 0
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            eng.enqueue(i, {"tokens": p}, SamplingParams(), {})
        while eng.has_work:
            eng.step()
        return time.perf_counter() - t0, eng.steps, eng.spec_stats

    t_plain, steps_plain, _ = measure(False)
    t_spec, steps_spec, st = measure(True)
    rate = st["accepted"] / max(1, st["proposed"])
    return [
        ("spec_decode_plain", t_plain * 1e6 / (n_requests * n_new),
         f"wall={t_plain:.3f}s engine_steps={steps_plain}"),
        ("spec_decode_ngram", t_spec * 1e6 / (n_requests * n_new),
         f"wall={t_spec:.3f}s engine_steps={steps_spec} "
         f"accept_rate={rate:.2f} speedup={t_plain/t_spec:.2f}x"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
