"""Online shared-prefix serving: block-level KV prefix caching on vs off.

Workload: N prompt families x M requests.  Every request in a family
shares a long prefix (system prompt / speaker embed / multi-turn history)
and appends a short unique suffix — the traffic shape that dominates
any-to-any serving at scale.  One warm request per family runs first (the
first arrival always computes), then M requests per family arrive as a
Poisson stream.  With the cache on, admission matches the family prefix's
pages, bumps their refcounts, and schedules only the suffix chunks, so
TTFT drops and the freed token budget admits later arrivals sooner.

Greedy sampling, and the harness asserts the generated tokens are
IDENTICAL with the cache on and off: reused pages hold bit-identical KV,
so prefix caching is a pure scheduling optimization.

  PYTHONPATH=src python -m benchmarks.bench_prefix_cache [--smoke]
      [--json OUT.json]
"""
from __future__ import annotations

import argparse
import queue as _queue
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import run_batch
from repro.configs.pipelines import tiny_lm
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T


def _build(prefix_cache: bool, *, max_batch: int, max_new: int,
           token_budget: int, chunk_size: int, seed: int) -> Orchestrator:
    cfg = tiny_lm("pfx_lm", vocab=512)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    kv = PagedKVConfig(num_pages=max_batch * 16 + 64, page_size=16,
                       max_pages_per_seq=16)
    eng = AREngine(
        "lm", cfg, params, kv=kv, max_batch=max_batch,
        token_budget=token_budget, chunk_size=chunk_size, stream_chunk=1,
        enable_prefix_cache=prefix_cache,
        default_sampling=SamplingParams(max_new_tokens=max_new,
                                        temperature=0.0))
    graph = StageGraph()
    graph.add_stage(StageSpec("lm", "ar", is_output=True))
    return Orchestrator(graph, {"lm": eng}, backend="threaded")


def _workload(n_families: int, per_family: int, prefix_len: int,
              suffix_max: int, seed: int):
    """(warm prompts, measured prompts): measured requests round-robin the
    families so hits and misses interleave like independent users."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, 500, prefix_len).astype(np.int32)
                for _ in range(n_families)]
    warm = [np.concatenate([p, rng.integers(0, 500, 4).astype(np.int32)])
            for p in prefixes]
    measured = []
    for j in range(per_family):
        for f in range(n_families):
            sfx = rng.integers(0, 500, int(rng.integers(4, suffix_max))
                               ).astype(np.int32)
            measured.append(np.concatenate([prefixes[f], sfx]))
    return warm, measured


def _tokens_of(req: Request) -> List[int]:
    out: List[int] = []
    for chunk in req.outputs.get("lm", []):
        out.extend(int(t) for t in chunk["tokens"])
    return out


def _serve(prefix_cache: bool, warm, measured, arrivals, *, max_batch: int,
           max_new: int, token_budget: int, chunk_size: int, seed: int,
           time_limit: float = 120.0):
    orch = _build(prefix_cache, max_batch=max_batch, max_new=max_new,
                  token_budget=token_budget, chunk_size=chunk_size,
                  seed=seed)
    # warm phase: the first request of each family computes (and, with the
    # cache on, publishes) its prefix — identical work in both modes
    run_batch(orch, [{"tokens": p} for p in warm])
    while True:
        try:
            orch.completions.get_nowait()
        except _queue.Empty:
            break
    # measured phase: Poisson arrivals (run_batch shut the workers down
    # after draining the warm batch — restart them)
    orch.start()
    n = len(measured)
    reqs: List[Request] = []
    done = i = 0
    t0 = time.perf_counter()
    while done < n and time.perf_counter() - t0 < time_limit:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            reqs.append(Request(inputs={"tokens": measured[i]}))
            orch.submit(reqs[-1])
            i += 1
        try:
            orch.completions.get(timeout=0.005)
            done += 1
        except _queue.Empty:
            pass
        if orch.worker_error:
            raise RuntimeError(f"stage worker died: {orch.worker_error}")
    wall = time.perf_counter() - t0
    stats = orch.engines["lm"].prefix_stats
    orch.shutdown(drain=False)
    ttfts = [r.first_output_time - r.arrival_time for r in reqs
             if r.first_output_time is not None]
    jcts = [r.jct for r in reqs if r.jct is not None]
    return {
        "reqs": reqs,
        "tokens": {r.req_id - reqs[0].req_id: _tokens_of(r) for r in reqs
                   if r.completion_time is not None},
        "done": done,
        "wall": wall,
        "ttft_mean": float(np.mean(ttfts)) if ttfts else float("nan"),
        "ttft_p95": (float(np.percentile(ttfts, 95)) if ttfts
                     else float("nan")),
        "jct_mean": float(np.mean(jcts)) if jcts else float("nan"),
        "stats": stats,
    }


def run(n_families: int = 3, per_family: int = 6, prefix_len: int = 96,
        suffix_max: int = 32, max_new: int = 8, rate_hz: float = 24.0,
        max_batch: int = 4, token_budget: int = 64, chunk_size: int = 32,
        seed: int = 0) -> list:
    warm, measured = _workload(n_families, per_family, prefix_len,
                               suffix_max, seed)
    rng = np.random.default_rng(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, len(measured)))

    kw = dict(max_batch=max_batch, max_new=max_new,
              token_budget=token_budget, chunk_size=chunk_size, seed=seed)
    off = _serve(False, warm, measured, arrivals, **kw)
    on = _serve(True, warm, measured, arrivals, **kw)

    # exact equality: prefix caching must not change a single token
    mismatches = sum(1 for k in on["tokens"]
                     if k in off["tokens"]
                     and on["tokens"][k] != off["tokens"][k])
    compared = len(set(on["tokens"]) & set(off["tokens"]))
    st = on["stats"]
    tot = st["cached_tokens"] + st["computed_tokens"]
    hit_rate = 100.0 * st["cached_tokens"] / tot if tot else 0.0
    speedup = off["ttft_mean"] / on["ttft_mean"] if on["ttft_mean"] else 0.0
    return [
        ("prefix_cache_off_ttft", off["ttft_mean"] * 1e6,
         f"mean={off['ttft_mean']*1e3:.1f}ms p95={off['ttft_p95']*1e3:.1f}ms "
         f"jct={off['jct_mean']*1e3:.1f}ms done={off['done']}"),
        ("prefix_cache_on_ttft", on["ttft_mean"] * 1e6,
         f"mean={on['ttft_mean']*1e3:.1f}ms p95={on['ttft_p95']*1e3:.1f}ms "
         f"jct={on['jct_mean']*1e3:.1f}ms done={on['done']} "
         f"speedup={speedup:.2f}x"),
        ("prefix_cache_hit_rate", hit_rate * 1e4,
         f"hits={st['hits']}/{st['lookups']} cached={st['cached_tokens']} "
         f"computed={st['computed_tokens']} tokens ({hit_rate:.1f}%)"),
        ("prefix_cache_token_equality", float(mismatches),
         f"{compared - mismatches}/{compared} requests byte-identical "
         f"on-vs-off"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for the pre-commit bench tier")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write machine-readable rows")
    args = ap.parse_args()
    kw = (dict(n_families=2, per_family=3, prefix_len=64, max_new=4,
               rate_hz=16.0) if args.smoke else {})
    rows = run(**kw)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        from benchmarks.run import write_json
        write_json(args.json, rows)


if __name__ == "__main__":
    main()
