"""Online serving: Poisson arrivals against the Thinker-Talker-Vocoder
pipeline with a deliberately slowed vocoder stage — the event-driven
per-stage-worker backend vs the lock-step tick loop.

Under lock-step, every tick steps every engine in topo order, so the
slowed vocoder's dwell is paid on the AR decoders' critical path and its
per-step batch stays shallow.  With per-stage workers the AR stages keep
decoding at full rate while the vocoder's inbox grows, and its queue
depth turns into LARGER per-step batches — fewer slow steps total.  JCT,
throughput and per-stage queueing delay quantify both effects (the online
complement of the paper's offline §4.2 evaluation).
"""
from __future__ import annotations

import queue as _queue
import time

import numpy as np

from benchmarks.common import SlowedEngine, prompts, warmup
from repro.configs.pipelines import build_qwen_omni
from repro.core.metrics import summarize, summarize_queueing
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def _build(backend: str, slow_ms: float, seed: int) -> Orchestrator:
    graph, engines, _ = build_qwen_omni(
        max_batch=4, thinker_tokens=6, talker_tokens=24, stream_chunk=8,
        dit_steps=2, seed=seed)
    if slow_ms > 0:
        engines["vocoder"] = SlowedEngine(engines["vocoder"], slow_ms * 1e-3)
    return Orchestrator(graph, engines, backend=backend)


def _serve_online(orch: Orchestrator, arrivals, ps, time_limit: float = 120.0):
    """Submit at the Poisson arrival instants, serve to completion."""
    n = len(ps)
    reqs, i = [], 0
    # warmup ran through this orchestrator: flush its completion stream and
    # baseline the completed list so both loops count ONLY the measured
    # requests (and both backends serve the same population)
    while True:
        try:
            orch.completions.get_nowait()
        except _queue.Empty:
            break
    done0 = len(orch.completed)
    t0 = time.perf_counter()
    if orch.backend == "threaded":
        orch.start()
        done = 0
        while done < n and time.perf_counter() - t0 < time_limit:
            now = time.perf_counter() - t0
            while i < n and arrivals[i] <= now:
                reqs.append(Request(inputs={"tokens": ps[i]}))
                orch.submit(reqs[-1])
                i += 1
            try:
                orch.completions.get(timeout=0.005)
                done += 1
            except _queue.Empty:
                pass
            if orch.worker_error:
                raise RuntimeError(f"stage worker died: {orch.worker_error}")
        wall = time.perf_counter() - t0
        # measured window is over — don't drain a possible backlog into it
        orch.shutdown(drain=False)
        return reqs, wall
    # lock-step baseline
    while len(orch.completed) - done0 < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            reqs.append(Request(inputs={"tokens": ps[i]}))
            orch.submit(reqs[-1])
            i += 1
        if not orch.tick() and i >= n and not any(
                orch.engines[s].has_work for s in orch.graph.stages):
            break
        if time.perf_counter() - t0 > time_limit:
            break
    return reqs, time.perf_counter() - t0


def run(n_requests: int = 12, rate_hz: float = 8.0, slow_ms: float = 60.0,
        seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    ps = prompts(n_requests, seed=seed)

    results = {}
    for backend in ("sync", "threaded"):
        orch = _build(backend, slow_ms, seed)
        warmup(orch, [{"tokens": p} for p in prompts(2, seed=42)])
        reqs, wall = _serve_online(orch, arrivals, ps)
        m = summarize(reqs, wall_time=wall)
        m["queueing"] = summarize_queueing(reqs)
        m["vocoder_steps"] = (orch.stage_metrics()["vocoder"]["steps"]
                              if backend == "threaded" else None)
        results[backend] = m

    sync_m, thr_m = results["sync"], results["threaded"]
    jct_red = 100 * (1 - thr_m["jct_mean"] / sync_m["jct_mean"])
    voc_q = thr_m["queueing"].get("vocoder", {"p95": float("nan")})
    thk_q = thr_m["queueing"].get("thinker", {"p95": float("nan")})
    return [
        ("online_jct_lockstep", sync_m["jct_mean"] * 1e6,
         f"p50={sync_m['jct_p50']:.3f}s p95={sync_m['jct_p95']:.3f}s "
         f"served={sync_m['req_per_s']:.2f}req/s (slow vocoder stalls all)"),
        ("online_jct_disagg", thr_m["jct_mean"] * 1e6,
         f"p50={thr_m['jct_p50']:.3f}s p95={thr_m['jct_p95']:.3f}s "
         f"served={thr_m['req_per_s']:.2f}req/s reduction={jct_red:.1f}%"),
        ("online_ttft_disagg", thr_m["ttft_p50"] * 1e6,
         f"p50={thr_m['ttft_p50']:.3f}s p95={thr_m['ttft_p95']:.3f}s "
         f"(streaming vocoder output)"),
        ("online_queue_delay_vocoder", voc_q["p95"] * 1e6,
         f"p95={voc_q['p95']*1e3:.1f}ms vs thinker "
         f"p95={thk_q['p95']*1e3:.1f}ms — backpressure stays on the slow "
         f"stage's own queue"),
    ]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for the pre-commit bench tier")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write machine-readable rows")
    args = ap.parse_args()
    kw = (dict(n_requests=6, rate_hz=8.0, slow_ms=20.0) if args.smoke
          else {})
    rows = run(**kw)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        from benchmarks.run import write_json
        write_json(args.json, rows)


if __name__ == "__main__":
    main()
