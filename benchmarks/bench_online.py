"""Online serving: Poisson arrivals against the Thinker-Talker-Vocoder
pipeline — JCT/TTFT percentiles under load (the online complement of the
paper's offline §4.2 evaluation)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import prompts, warmup
from repro.configs.pipelines import build_qwen_omni
from repro.core.metrics import summarize
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request


def run(n_requests: int = 10, rate_hz: float = 4.0, seed: int = 0) -> list:
    graph, engines, _ = build_qwen_omni(
        max_batch=4, thinker_tokens=6, talker_tokens=24, stream_chunk=8,
        dit_steps=2, seed=seed)
    orch = Orchestrator(graph, engines)
    warmup(orch, [{"tokens": p} for p in prompts(2, seed=42)])

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    arrivals = np.cumsum(gaps)
    ps = prompts(n_requests, seed=seed)

    t0 = time.perf_counter()
    reqs = []
    i = 0
    while len(orch.completed) < n_requests:
        now = time.perf_counter() - t0
        while i < n_requests and arrivals[i] <= now:
            r = Request(inputs={"tokens": ps[i]})
            reqs.append(r)
            orch.submit(r)
            i += 1
        if not orch.tick() and i >= n_requests and not any(
                engines[n].has_work for n in graph.stages):
            break
        if time.perf_counter() - t0 > 120:
            break
    wall = time.perf_counter() - t0
    m = summarize(reqs, wall_time=wall)
    return [
        ("online_jct", m["jct_mean"] * 1e6,
         f"p50={m['jct_p50']:.3f}s p95={m['jct_p95']:.3f}s "
         f"rate={rate_hz}req/s served={m['req_per_s']:.2f}req/s"),
        ("online_ttft", m["ttft_p50"] * 1e6,
         f"p50={m['ttft_p50']:.3f}s p95={m['ttft_p95']:.3f}s "
         f"(streaming vocoder output)"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
