"""Radix-tree prefix index vs the flat content-hash map.

Three measurements:

  1. **Online shared-prefix serving, radix vs flat** — N prompt families
     whose shared prefixes are NOT page-aligned (the realistic case: a
     system prompt rarely ends on a block boundary).  The flat map can
     only hit the full blocks; the radix tree also matches the leading
     tokens of the diverging block (partial-block hit, materialized via
     copy-on-write), so every request re-computes fewer prompt tokens.
     Greedy outputs are asserted byte-identical between the two indexes.

  2. **Probe microbench** — ``prefix_hint`` latency on a populated index:
     the radix walk must stay within noise of the flat dict probe while
     additionally scoring partial hits.

  3. **Warm vs cold scale-up** — a donor engine serves a family workload,
     then two fresh engines serve the same trace: one seeded from the
     donor's ``prefix_snapshot`` (the ReplicaSet.scale_up path), one
     cold.  The warm replica's cumulative prefix hit rate over its first
     requests is higher and its prompt recompute cost lower.

  PYTHONPATH=src python -m benchmarks.bench_radix [--smoke]
      [--json OUT.json]
"""
from __future__ import annotations

import argparse
import queue as _queue
import time
from typing import List

import jax
import numpy as np

from benchmarks.common import run_batch
from repro.configs.pipelines import tiny_lm
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import (PagedKVConfig, hash_token_blocks,
                                   token_prefix_keys)
from repro.engine.radix_index import FlatIndex, RadixIndex
from repro.engine.sampling import SamplingParams
from repro.models import transformer as T

PAGE = 16


def _engine(index_kind: str, *, max_batch: int, max_new: int,
            token_budget: int, seed: int, num_pages: int = 0) -> AREngine:
    cfg = tiny_lm("radix_lm", vocab=512)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    kv = PagedKVConfig(num_pages=num_pages or max_batch * 16 + 64,
                       page_size=PAGE, max_pages_per_seq=16)
    return AREngine(
        "lm", cfg, params, kv=kv, max_batch=max_batch,
        token_budget=token_budget, chunk_size=32, stream_chunk=1,
        enable_prefix_cache=True, prefix_index=index_kind,
        default_sampling=SamplingParams(max_new_tokens=max_new,
                                        temperature=0.0))


def _orch(index_kind: str, **kw) -> Orchestrator:
    graph = StageGraph()
    graph.add_stage(StageSpec("lm", "ar", is_output=True))
    return Orchestrator(graph, {"lm": _engine(index_kind, **kw)},
                        backend="threaded")


def _workload(n_families: int, per_family: int, prefix_len: int,
              suffix_max: int, seed: int):
    """Families with a NON-page-aligned shared prefix: full-block hits
    cover prefix_len // PAGE blocks, the remaining prefix_len % PAGE
    shared tokens are reachable only through partial-block matching."""
    assert prefix_len % PAGE != 0, "prefix must spill into a partial block"
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, 500, prefix_len).astype(np.int32)
                for _ in range(n_families)]
    warm = [np.concatenate([p, rng.integers(0, 500, 4).astype(np.int32)])
            for p in prefixes]
    measured = []
    for _ in range(per_family):
        for f in range(n_families):
            sfx = rng.integers(0, 500, int(rng.integers(4, suffix_max))
                               ).astype(np.int32)
            measured.append(np.concatenate([prefixes[f], sfx]))
    return warm, measured


def _tokens_of(req: Request) -> List[int]:
    out: List[int] = []
    for chunk in req.outputs.get("lm", []):
        out.extend(int(t) for t in chunk["tokens"])
    return out


def _serve_poisson(index_kind: str, warm, measured, arrivals, *,
                   time_limit: float = 120.0, **kw):
    orch = _orch(index_kind, **kw)
    run_batch(orch, [{"tokens": p} for p in warm])   # publish the families
    # shape warmup (symmetric for both index kinds): one shared-prefix
    # request triggers the hit-admission path — and, for radix, the
    # partial-chunk prefill shape — so jit compile time lands outside the
    # measured window instead of skewing the first TTFT sample
    rngw = np.random.default_rng(4242)
    shape_warm = np.concatenate(
        [warm[0][:-4], rngw.integers(0, 500, 6).astype(np.int32)])
    run_batch(orch, [{"tokens": shape_warm}])
    stats0 = dict(orch.engines["lm"].prefix_stats)
    while True:
        try:
            orch.completions.get_nowait()
        except _queue.Empty:
            break
    orch.start()
    n = len(measured)
    reqs: List[Request] = []
    done = i = 0
    t0 = time.perf_counter()
    while done < n and time.perf_counter() - t0 < time_limit:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            reqs.append(Request(inputs={"tokens": measured[i]}))
            orch.submit(reqs[-1])
            i += 1
        try:
            orch.completions.get(timeout=0.005)
            done += 1
        except _queue.Empty:
            pass
        if orch.worker_error:
            raise RuntimeError(f"stage worker died: {orch.worker_error}")
    stats = {k: v - stats0.get(k, 0)
             for k, v in orch.engines["lm"].prefix_stats.items()}
    orch.shutdown(drain=False)
    ttfts = [r.first_output_time - r.arrival_time for r in reqs
             if r.first_output_time is not None]
    return {
        "tokens": {r.req_id - reqs[0].req_id: _tokens_of(r) for r in reqs
                   if r.completion_time is not None},
        "done": done,
        "ttft_mean": float(np.mean(ttfts)) if ttfts else float("nan"),
        "stats": stats,
    }


# ---------------------------------------------------------------------------
# probe microbench (pure python, no model)
# ---------------------------------------------------------------------------

def _probe_bench(n_chains: int, depth_pages: int, n_probes: int, seed: int):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 500, depth_pages * PAGE).astype(np.int64)
    seqs = []
    for _ in range(n_chains):
        cut = int(rng.integers(0, depth_pages * PAGE))
        tail = rng.integers(0, 500, depth_pages * PAGE - cut)
        seqs.append(np.concatenate([base[:cut], tail.astype(np.int64)]))
    radix, flat = RadixIndex(), FlatIndex()
    next_page = 0
    for s in seqs:
        hashes = hash_token_blocks(s, PAGE)
        keys = token_prefix_keys(s, PAGE)
        pages = []
        for h in hashes:                 # same page for same hash
            node = radix._by_hash.get(h)
            pages.append(node.page if node else next_page)
            if node is None:
                next_page += 1
        radix.insert(hashes, pages, keys)
        flat.insert(hashes, pages, keys)
    probes = []
    for _ in range(n_probes):
        s = seqs[int(rng.integers(0, len(seqs)))]
        cut = int(rng.integers(1, len(s)))
        probe = np.concatenate([s[:cut],
                                rng.integers(500, 512, len(s) - cut)])
        probes.append((hash_token_blocks(probe, PAGE),
                       token_prefix_keys(probe, PAGE)))
    out = {}
    for name, idx in (("radix", radix), ("flat", flat)):
        t0 = time.perf_counter()
        score = 0
        for hashes, keys in probes:
            score += idx.hint(hashes, keys, PAGE)
        out[name] = ((time.perf_counter() - t0) / n_probes, score)
    return out, len(radix)


# ---------------------------------------------------------------------------
# warm vs cold scale-up (engine level: the ReplicaSet._warm_seed path)
# ---------------------------------------------------------------------------

def _serve_sequential(eng: AREngine, prompts, base_stats):
    """One request at a time; returns per-request wall times and the
    cumulative prefix hit-rate trajectory (warm-seed deltas excluded via
    ``base_stats``)."""
    walls, traj = [], []
    for i, p in enumerate(prompts):
        t0 = time.perf_counter()
        eng.enqueue(10_000 + i, {"tokens": p}, SamplingParams(), {})
        for _ in range(10_000):
            eng.step()
            if not eng.has_work:
                break
        walls.append(time.perf_counter() - t0)
        st = eng.prefix_stats
        cached = st["cached_tokens"] - base_stats["cached_tokens"]
        comp = st["computed_tokens"] - base_stats["computed_tokens"]
        traj.append(cached / (cached + comp) if cached + comp else 0.0)
    return walls, traj


def _warm_vs_cold(*, n_families: int, per_family: int, prefix_len: int,
                  suffix_max: int, max_new: int, seed: int, **kw):
    warm, measured = _workload(n_families, per_family, prefix_len,
                               suffix_max, seed + 7)
    ekw = dict(max_batch=kw["max_batch"], max_new=max_new,
               token_budget=kw["token_budget"], seed=seed)
    donor = _engine("radix", **ekw)
    _serve_sequential(donor, warm, dict.fromkeys(
        ("cached_tokens", "computed_tokens"), 0))
    snap = donor.prefix_snapshot(max_pages=64)
    engines = {"warm": _engine("radix", **ekw),
               "cold": _engine("radix", **ekw)}
    rng = np.random.default_rng(seed + 11)
    # jit-compile warmup on a disjoint token range so neither engine pays
    # compile time inside the measured trace (and neither gains hits on
    # the family prefixes): the second prompt shares the first's prefix,
    # compiling the hit-admission (full + partial CoW) shapes too
    throwaway = rng.integers(505, 512, prefix_len + 8).astype(np.int32)
    throw2 = np.concatenate(
        [throwaway[:-6], rng.integers(500, 505, 4).astype(np.int32)])
    for eng in engines.values():
        _serve_sequential(eng, [throwaway, throw2], dict.fromkeys(
            ("cached_tokens", "computed_tokens"), 0))
    seeded = engines["warm"].seed_prefixes(snap)
    out = {}
    for name, eng in engines.items():
        base = dict(eng.prefix_stats)
        walls, traj = _serve_sequential(eng, measured, base)
        out[name] = {"walls": walls, "traj": traj,
                     "stats": {k: eng.prefix_stats[k] - base[k]
                               for k in base}}
    out["seeded_pages"] = seeded
    return out


def run(n_families: int = 3, per_family: int = 6, prefix_len: int = 90,
        suffix_max: int = 32, max_new: int = 8, rate_hz: float = 24.0,
        max_batch: int = 4, token_budget: int = 64, seed: int = 0,
        probe_chains: int = 64, probe_depth: int = 8,
        n_probes: int = 2000) -> list:
    warm, measured = _workload(n_families, per_family, prefix_len,
                               suffix_max, seed)
    rng = np.random.default_rng(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, len(measured)))
    kw = dict(max_batch=max_batch, max_new=max_new,
              token_budget=token_budget, seed=seed)

    flat = _serve_poisson("flat", warm, measured, arrivals, **kw)
    radix = _serve_poisson("radix", warm, measured, arrivals, **kw)

    mismatches = sum(1 for k in radix["tokens"]
                     if k in flat["tokens"]
                     and radix["tokens"][k] != flat["tokens"][k])
    compared = len(set(radix["tokens"]) & set(flat["tokens"]))
    st = radix["stats"]
    tot = st["cached_tokens"] + st["computed_tokens"]
    part_rate = 100.0 * st["partial_tokens"] / tot if tot else 0.0
    speedup = (flat["ttft_mean"] / radix["ttft_mean"]
               if radix["ttft_mean"] else 0.0)

    probes, idx_pages = _probe_bench(probe_chains, probe_depth, n_probes,
                                     seed + 3)
    wc = _warm_vs_cold(n_families=n_families, per_family=per_family,
                       prefix_len=prefix_len, suffix_max=suffix_max,
                       max_new=max_new, seed=seed, max_batch=max_batch,
                       token_budget=token_budget)
    n_first = min(len(measured), 2 * n_families)
    warm_hr = wc["warm"]["traj"][n_first - 1] if wc["warm"]["traj"] else 0.0
    cold_hr = wc["cold"]["traj"][n_first - 1] if wc["cold"]["traj"] else 0.0
    warm_wall = float(np.mean(wc["warm"]["walls"]))
    cold_wall = float(np.mean(wc["cold"]["walls"]))

    return [
        ("radix_flat_index_ttft", flat["ttft_mean"] * 1e6,
         f"mean={flat['ttft_mean']*1e3:.1f}ms done={flat['done']} "
         f"cached={flat['stats']['cached_tokens']} "
         f"(partial={flat['stats']['partial_tokens']})"),
        ("radix_tree_index_ttft", radix["ttft_mean"] * 1e6,
         f"mean={radix['ttft_mean']*1e3:.1f}ms done={radix['done']} "
         f"cached={st['cached_tokens']} "
         f"(full={st['full_block_tokens']} partial={st['partial_tokens']} "
         f"in {st['partial_hits']} hits) speedup={speedup:.2f}x"),
        ("radix_partial_hit_rate", part_rate * 1e4,
         f"{st['partial_tokens']} partial-hit tokens of {tot} "
         f"({part_rate:.1f}%) — flat map structurally gets 0"),
        ("radix_token_equality", float(mismatches),
         f"{compared - mismatches}/{compared} requests byte-identical "
         f"radix-vs-flat"),
        ("radix_probe_lookup", probes["radix"][0] * 1e6,
         f"hint() over {idx_pages}-page tree: "
         f"{probes['radix'][0]*1e9:.0f}ns/probe "
         f"(flat {probes['flat'][0]*1e9:.0f}ns) "
         f"score {probes['radix'][1]} vs {probes['flat'][1]} tokens"),
        ("warm_seed_scaleup_wall", warm_wall * 1e6,
         f"warm-seeded replica: {wc['seeded_pages']} pages seeded, "
         f"mean req wall {warm_wall*1e3:.1f}ms vs cold "
         f"{cold_wall*1e3:.1f}ms"),
        ("warm_seed_hit_rate", warm_hr * 1e6,
         f"cumulative hit rate after first {n_first} reqs: "
         f"warm={warm_hr:.3f} cold={cold_hr:.3f} "
         f"(warm cached {wc['warm']['stats']['cached_tokens']} vs cold "
         f"{wc['cold']['stats']['cached_tokens']} tokens)"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for the pre-commit bench tier")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write machine-readable rows")
    args = ap.parse_args()
    kw = (dict(n_families=2, per_family=3, prefix_len=42, max_new=4,
               rate_hz=16.0, probe_chains=16, n_probes=400)
          if args.smoke else {})
    rows = run(**kw)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        from benchmarks.run import write_json
        write_json(args.json, rows)


if __name__ == "__main__":
    main()
