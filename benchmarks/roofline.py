"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and, per
(arch x shape x mesh), derives the three roofline terms:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory     = HLO_bytes_per_device / HBM_bw               [s]
    collective = collective_bytes_per_device / ICI_link_bw   [s]

cost_analysis() runs on the GSPMD-partitioned module, so its numbers are
already per-device. collective_bytes sums result-tensor bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
in the partitioned HLO (a lower bound on wire traffic; all-reduce moves
~2x its payload on a ring — noted, not corrected).

MODEL_FLOPS (useful work): 6·N·T train / 2·N·T prefill / 2·N·B decode,
with N_active for MoE. The ratio MODEL_FLOPS/HLO_FLOPs exposes remat and
dispatch waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.base import INPUT_SHAPES, get_config, variant_for_shape
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def analyze_record(rec: dict) -> dict | None:
    """Derive the three roofline terms for one dry-run record.

    compute & memory come from the ANALYTIC model (flops_model.py) because
    XLA's cost_analysis counts while-loop bodies once; the collective term
    comes from the loop-corrected HLO parse done by dryrun.py. All terms
    are per-chip seconds.
    """
    from benchmarks.flops_model import estimate
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec["mesh"].startswith("2x") else 256
    cfg = variant_for_shape(get_config(rec["arch"]),
                            INPUT_SHAPES[rec["shape"]])
    if rec.get("kv_cache_dtype"):
        cfg = cfg.replace(kv_cache_dtype=rec["kv_cache_dtype"])
    if rec.get("padded_heads"):
        cfg = cfg.replace(num_heads=rec["padded_heads"][0],
                          num_kv_heads=rec["padded_heads"][1])
    est = estimate(cfg, INPUT_SHAPES[rec["shape"]])
    flops = est.flops / chips
    nbytes = est.hbm_bytes / chips
    coll = rec.get("collective_bytes", {}).get("total", 0)  # per-device HLO
    t_c = flops / PEAK_FLOPS_BF16
    t_m = nbytes / HBM_BW
    t_x = coll / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = est.model_flops / chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "flops": flops, "bytes": nbytes, "coll_bytes": coll,
        "hlo_flops_raw": rec.get("flops", 0.0),
        "variant": rec.get("attn_variant", "full"),
    }


def load_all(dirname: str) -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        a = analyze_record(rec)
        if a:
            out.append(a)
        elif rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "dominant": "SKIPPED",
                        "reason": rec.get("reason", "")})
    return out


def fmt_table(rows: list, mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful FLOPs ratio |")
    sep = "|" + "---|" * 7
    lines = [hdr, sep]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["dominant"] == "SKIPPED":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | {r['reason']} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} ({r['variant']}) "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.3f} |")
    return "\n".join(lines)


def run(dirname: str = "experiments/dryrun") -> list:
    rows = load_all(dirname)
    out = []
    for r in rows:
        if r["dominant"] == "SKIPPED" or r["mesh"] != "16x16":
            continue
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        out.append((f"roofline_{r['arch']}_{r['shape']}",
                    max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6,
                    f"dominant={r['dominant']} useful={r['useful_ratio']:.2f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(fmt_table(rows, args.mesh))


if __name__ == "__main__":
    main()
