"""Multi-replica stage serving: scaling, cache-affinity routing, autoscale.

Three measurements (paper §3.2, flexible resource allocation):

  A. replica scaling — a slowed bottleneck stage under Poisson overload,
     served by 1 vs 2 replicas.  Dwell is a sleep (releases the GIL, like
     real device work), so 2 replicas should approach 2x finished/s.
  B. cache-affinity routing — shared-prefix traffic over 2 replicas.
     ``affinity`` routes each prefix family to the replica already holding
     its pages, keeping the aggregate prefix hit rate at the 1-replica
     level; ``round_robin`` splits families across replicas and pays the
     cold-miss on both.
  C. metrics-driven autoscale — a 2-stage pipeline with one hot stage,
     static even replica split vs the ScalingController moving a replica
     from the cold stage to the bottleneck at runtime (same budget).

  D. process isolation overhead — the same slowed-stage workload served
     by 2 ``isolation="process"`` replicas: spawned workers, items over
     named shared-memory segments.  Compares against B's threaded
     2-replica rate to price the cross-process hop.

  PYTHONPATH=src python -m benchmarks.bench_replicas [--smoke]
      [--json OUT.json]
"""
from __future__ import annotations

import argparse
import queue as _queue
import time
from typing import Dict, List

import jax
import numpy as np

from repro.configs.pipelines import tiny_lm
from repro.core.config import EngineSpec, ServeConfig, StageConfig
from repro.core.graph import StageGraph
from repro.core.orchestrator import Orchestrator
from repro.core.request import Request
from repro.core.scaling import ScalingConfig, ScalingController
from repro.core.stage import StageSpec
from repro.engine.ar_engine import AREngine
from repro.engine.kv_cache import PagedKVConfig
from repro.engine.sampling import SamplingParams
from repro.engine.stub_engine import StubEngine
from repro.models import transformer as T


def _poisson_serve(orch: Orchestrator, inputs_list, rate_hz: float,
                   seed: int, time_limit: float = 60.0):
    """Submit a Poisson stream, consume completions; returns (reqs, wall)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, len(inputs_list)))
    orch.start()
    reqs: List[Request] = []
    done = i = 0
    t0 = time.perf_counter()
    while done < len(inputs_list):
        now = time.perf_counter() - t0
        while i < len(inputs_list) and arrivals[i] <= now:
            reqs.append(Request(inputs=inputs_list[i]))
            orch.submit(reqs[-1])
            i += 1
        try:
            orch.completions.get(timeout=0.002)
            done += 1
        except _queue.Empty:
            pass
        if orch.worker_error:
            raise RuntimeError(orch.worker_error)
        if now > time_limit:
            break
    wall = time.perf_counter() - t0
    return reqs, wall


# ----------------------------------------------------------------------------
# A. replica scaling on a slowed bottleneck stage
# ----------------------------------------------------------------------------

def _scaling(n_requests: int, dwell_s: float, seed: int) -> Dict[str, float]:
    out = {}
    rate = 6.0 / dwell_s            # overload even the 2-replica config
    # (well past 2x capacity, so the wall clock measures service rate,
    # not the arrival window)
    for n_rep in (1, 2):
        graph = StageGraph()
        graph.add_stage(StageSpec("slow", "custom", is_output=True))
        engines = {"slow": [StubEngine("slow", dwell_s)
                            for _ in range(n_rep)]}
        orch = Orchestrator(graph, engines,
                            config=ServeConfig(routing="least_loaded"))
        reqs, wall = _poisson_serve(
            orch, [{"x": i} for i in range(n_requests)], rate, seed)
        orch.shutdown(drain=False)
        ok = sum(1 for r in reqs if r.completion_time is not None
                 and not r.failed)
        out[n_rep] = ok / wall
    return out


def _process_scaling(n_requests: int, dwell_s: float, seed: int) -> float:
    """D: the 2-replica scaling run again, but each replica is a spawned
    process worker fed through shared-memory segments."""
    graph = StageGraph()
    graph.add_stage(StageSpec("slow", "custom", is_output=True))
    spec = EngineSpec("repro.engine.stub_engine:make_stub",
                      {"name": "slow", "dwell_ms": dwell_s * 1e3})
    config = ServeConfig(routing="least_loaded", stages={
        "slow": StageConfig(replicas=2, isolation="process",
                            engine_spec=spec)})
    orch = Orchestrator(graph, {"slow": StubEngine("slow", dwell_s)},
                        config=config)
    orch.start()
    for _, w in orch._workers["slow"].workers():
        w.wait_ready(60.0)               # keep spawn cost out of the window
    reqs, wall = _poisson_serve(
        orch, [{"x": i} for i in range(n_requests)], 6.0 / dwell_s, seed)
    orch.shutdown(drain=False)
    ok = sum(1 for r in reqs if r.completion_time is not None
             and not r.failed)
    return ok / wall


# ----------------------------------------------------------------------------
# B. cache-affinity routing vs round-robin on shared-prefix traffic
# ----------------------------------------------------------------------------

def _affinity_orch(n_rep: int, routing: str, *, max_batch: int,
                   max_new: int, seed: int) -> Orchestrator:
    cfg = tiny_lm("aff_lm", vocab=512)
    params = T.init_params(cfg, jax.random.PRNGKey(seed))
    kv = PagedKVConfig(num_pages=max_batch * 16 + 64, page_size=16,
                       max_pages_per_seq=16)

    def make_engine():
        return AREngine(
            "lm", cfg, params, kv=kv, max_batch=max_batch,
            token_budget=64, chunk_size=32, enable_prefix_cache=True,
            default_sampling=SamplingParams(max_new_tokens=max_new,
                                            temperature=0.0))

    graph = StageGraph()
    graph.add_stage(StageSpec("lm", "ar", is_output=True))
    config = ServeConfig(routing=routing, stages={
        "lm": StageConfig(replicas=n_rep, engine_factory=make_engine)})
    return Orchestrator(graph, {"lm": make_engine()}, config=config)


def _affinity_hit_rate(n_rep: int, routing: str, *, families: int,
                       per_family: int, prefix_len: int, max_new: int,
                       seed: int) -> float:
    """Serve warm + measured shared-prefix traffic sequentially (each
    request completes — and publishes — before the next routes) and
    return the aggregate prefix-cache hit rate across replicas."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, 500, prefix_len).astype(np.int32)
                for _ in range(families)]
    prompts = [np.concatenate([p, rng.integers(0, 500, 4).astype(np.int32)])
               for p in prefixes]          # warm: first arrival per family
    for _ in range(per_family):
        for f in range(families):
            sfx = rng.integers(0, 500, int(rng.integers(4, 12))
                               ).astype(np.int32)
            prompts.append(np.concatenate([prefixes[f], sfx]))
    orch = _affinity_orch(n_rep, routing, max_batch=4, max_new=max_new,
                          seed=seed)
    orch.start()
    for p in prompts:
        orch.submit(Request(inputs={"tokens": p}))
        r = orch.completions.get(timeout=30.0)
        if r.failed:
            raise RuntimeError(r.failed)
    stats = {"cached_tokens": 0, "computed_tokens": 0}
    for eng in orch._live_engines("lm"):
        for k in stats:
            stats[k] += eng.prefix_stats[k]
    orch.shutdown(drain=False)
    tot = stats["cached_tokens"] + stats["computed_tokens"]
    return stats["cached_tokens"] / tot if tot else 0.0


# ----------------------------------------------------------------------------
# C. autoscale: move a replica to the bottleneck at runtime
# ----------------------------------------------------------------------------

def _two_stage(heavy_s: float, light_s: float, heavy_reps: int,
               light_reps: int):
    graph = StageGraph()
    graph.add_stage(StageSpec("pre", "custom"))
    graph.add_stage(StageSpec("gen", "custom", is_output=True))
    graph.add_edge("pre", "gen", lambda d, p: p, connector="inline")
    engines = {"pre": [StubEngine("pre", light_s)
                       for _ in range(light_reps)],
               "gen": [StubEngine("gen", heavy_s)
                       for _ in range(heavy_reps)]}
    config = ServeConfig(routing="least_loaded", stages={
        "pre": StageConfig(engine_factory=lambda: StubEngine("pre",
                                                             light_s)),
        "gen": StageConfig(engine_factory=lambda: StubEngine("gen",
                                                             heavy_s))})
    return Orchestrator(graph, engines, config=config)


def _autoscale(n_requests: int, heavy_s: float, seed: int):
    light_s = heavy_s / 12.0
    rate = 4.0 / heavy_s            # well past the 2-replica gen capacity
    inputs = [{"x": i} for i in range(n_requests)]

    orch = _two_stage(heavy_s, light_s, 2, 2)          # static even split
    reqs, _ = _poisson_serve(orch, inputs, rate, seed)
    orch.shutdown(drain=False)
    static_jct = float(np.mean([r.jct for r in reqs if r.jct is not None]))

    orch = _two_stage(heavy_s, light_s, 2, 2)          # same budget of 4
    scaler = ScalingController(orch, ScalingConfig(
        interval=0.08, cooldown=1, hi=0.75, lo=0.40,
        replica_budget=4)).start()
    reqs, _ = _poisson_serve(orch, inputs, rate, seed)
    actions = list(scaler.actions)
    counts = orch.replica_counts()
    orch.shutdown(drain=False)
    dyn_jct = float(np.mean([r.jct for r in reqs if r.jct is not None]))
    return static_jct, dyn_jct, actions, counts


# ----------------------------------------------------------------------------

def run(n_requests: int = 24, dwell_ms: float = 20.0, families: int = 4,
        per_family: int = 6, prefix_len: int = 48, max_new: int = 6,
        autoscale_requests: int = 60, seed: int = 0) -> list:
    rows = []

    thr = _scaling(n_requests, dwell_ms / 1e3, seed)
    speedup = thr[2] / thr[1] if thr[1] else 0.0
    rows.append(("replicas_1x_finished_per_s", thr[1] * 1e3,
                 f"{thr[1]:.1f} req/s (dwell {dwell_ms:.0f}ms)"))
    rows.append(("replicas_2x_finished_per_s", thr[2] * 1e3,
                 f"{thr[2]:.1f} req/s speedup={speedup:.2f}x"))

    proc = _process_scaling(n_requests, dwell_ms / 1e3, seed)
    ratio = proc / thr[2] if thr[2] else 0.0
    rows.append(("replicas_2x_process_finished_per_s", proc * 1e3,
                 f"{proc:.1f} req/s isolation=process "
                 f"({100*ratio:.0f}% of threaded 2x)"))

    base = _affinity_hit_rate(1, "affinity", families=families,
                              per_family=per_family, prefix_len=prefix_len,
                              max_new=max_new, seed=seed)
    aff = _affinity_hit_rate(2, "affinity", families=families,
                             per_family=per_family, prefix_len=prefix_len,
                             max_new=max_new, seed=seed)
    rr = _affinity_hit_rate(2, "round_robin", families=families,
                            per_family=per_family, prefix_len=prefix_len,
                            max_new=max_new, seed=seed)
    rows.append(("affinity_hit_rate_1rep", base * 1e4,
                 f"{base*100:.1f}% (single-replica baseline)"))
    rows.append(("affinity_hit_rate_2rep", aff * 1e4,
                 f"{aff*100:.1f}% affinity routing "
                 f"(drop {100*(base-aff):.1f} pts)"))
    rows.append(("round_robin_hit_rate_2rep", rr * 1e4,
                 f"{rr*100:.1f}% round-robin "
                 f"(drop {100*(base-rr):.1f} pts)"))

    static_jct, dyn_jct, actions, counts = _autoscale(
        autoscale_requests, dwell_ms / 1e3, seed)
    moved = sum(1 for a in actions if a["stage"] == "gen")
    rows.append(("autoscale_static_jct", static_jct * 1e6,
                 f"mean={static_jct*1e3:.0f}ms (even 2/2 split)"))
    rows.append(("autoscale_dynamic_jct", dyn_jct * 1e6,
                 f"mean={dyn_jct*1e3:.0f}ms actions={len(actions)} "
                 f"to_bottleneck={moved} final={counts} "
                 f"improvement={static_jct/dyn_jct:.2f}x"
                 if dyn_jct else "no completions"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for the pre-commit bench tier")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write machine-readable rows")
    args = ap.parse_args()
    kw = (dict(n_requests=16, dwell_ms=15.0, families=3, per_family=4,
               max_new=4, autoscale_requests=40) if args.smoke else {})
    rows = run(**kw)
    for r in rows:
        print(",".join(map(str, r)))
    if args.json:
        from benchmarks.run import write_json
        write_json(args.json, rows)


if __name__ == "__main__":
    main()
