"""Figure 8 reproduction: diffusion engine vs naive Diffusers-style loop.

The paper's diffusion engine wins come from request batching + operator
reuse + denoise caching; here we measure (a) per-request sequential
denoising (Diffusers-like), (b) batched engine, (c) batched engine with
TeaCache-style velocity reuse (cache_interval=2).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.dit import DiTConfig, init_dit, sample


def run(n_requests: int = 8, cond_len: int = 24, out_len: int = 48,
        steps: int = 8, seed: int = 0) -> list:
    cfg = DiTConfig(num_layers=2, d_model=128, num_heads=4, d_ff=256,
                    in_dim=32, cond_dim=128, num_steps=steps)
    params = init_dit(cfg, jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    conds = jax.random.normal(key, (n_requests, cond_len, cfg.cond_dim))

    f1 = jax.jit(lambda p, c, k: sample(cfg, p, c, out_len, k))
    fb = jax.jit(lambda p, c, k: sample(cfg, p, c, out_len, k))
    fc = jax.jit(lambda p, c, k: sample(cfg, p, c, out_len, k,
                                        cache_interval=2))
    # warm
    f1(params, conds[:1], key).block_until_ready()
    fb(params, conds, key).block_until_ready()
    fc(params, conds, key).block_until_ready()

    t0 = time.perf_counter()
    for i in range(n_requests):
        f1(params, conds[i:i + 1], key).block_until_ready()
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    fb(params, conds, key).block_until_ready()
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    out_c = fc(params, conds, key)
    out_c.block_until_ready()
    t_cache = time.perf_counter() - t0

    # quality proxy: cached output stays finite and near the exact one
    out_b = np.asarray(fb(params, conds, key))
    drift = float(np.mean(np.abs(np.asarray(out_c) - out_b))
                  / (np.mean(np.abs(out_b)) + 1e-9))

    return [
        ("fig8_diffusers_like_seq", t_seq * 1e6 / n_requests,
         f"total={t_seq:.3f}s"),
        ("fig8_engine_batched", t_batch * 1e6 / n_requests,
         f"speedup={t_seq/t_batch:.2f}x"),
        ("fig8_engine_batched_teacache", t_cache * 1e6 / n_requests,
         f"speedup={t_seq/t_cache:.2f}x drift={drift:.3f}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
