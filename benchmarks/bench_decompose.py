"""Figure 7 reproduction: per-stage execution-time decomposition for the
Thinker-Talker pipeline (Qwen3-Omni style, CNN vocoder). The paper's
finding: the Talker dominates because it generates ~3.6x more tokens."""
from __future__ import annotations


from benchmarks.common import prompts, run_batch, warmup
from repro.configs.pipelines import build_qwen_omni
from repro.core.orchestrator import Orchestrator


def run(n_requests: int = 6, thinker_tokens: int = 10,
        talker_tokens: int = 36, seed: int = 0) -> list:
    graph, engines, _ = build_qwen_omni(
        max_batch=4, thinker_tokens=thinker_tokens,
        talker_tokens=talker_tokens, stream_chunk=12, vocoder_kind="cnn",
        seed=seed)
    orch = Orchestrator(graph, engines)
    warmup(orch, [{"tokens": p} for p in prompts(2, seed=77)])
    run_batch(orch, [{"tokens": p} for p in prompts(n_requests, seed=seed)])
    busy = orch.stage_busy_times()
    total = sum(busy.values())
    rows = []
    for st, t in busy.items():
        rows.append((f"fig7_{st}_time", t * 1e6 / n_requests,
                     f"share={100*t/total:.1f}%"))
    talker_dominates = busy["talker"] >= max(busy.values()) * 0.999
    rows.append(("fig7_talker_dominates", 0.0,
                 f"{'yes' if talker_dominates else 'no'} "
                 f"(paper: talker accounts for most latency)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
