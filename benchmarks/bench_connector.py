"""Table 1 reproduction: unified-connector transfer latency.

Thinker2Talker payload (hidden states) and Talker2Vocoder payload (codec
tokens) over shared-memory and Mooncake backends; overhead must be
negligible vs end-to-end inference (paper: 5.5/8.3 ms vs tens of seconds).
"""
from __future__ import annotations

import time

import numpy as np

from repro.connector.mooncake import make_connector


def _measure(kind: str, payload, iters: int = 50) -> tuple:
    conn = make_connector(kind)
    conn.send("w", payload)
    conn.recv("w", timeout=5.0)        # warm
    conn.release("w")                  # end the warm key's lifetime
    t0 = time.perf_counter()
    for i in range(iters):
        conn.send(f"k{i}", payload)
        conn.recv(f"k{i}", timeout=5.0)
        conn.release(f"k{i}")
    wall = (time.perf_counter() - t0) / iters
    return wall, conn.stats.modeled_time / (iters + 1)


def run(hidden_len: int = 150, d: int = 896, codec_len: int = 545) -> list:
    # payload sizes mirror the paper's measured averages (§4.2): ~150 text
    # tokens' hidden states, ~545 codec tokens
    t2t = {"hidden": np.random.randn(hidden_len, d).astype(np.float32),
           "tokens": np.random.randint(0, 3000, hidden_len).astype(np.int32)}
    t2v = {"tokens": np.random.randint(0, 3000, codec_len).astype(np.int32)}
    # intra-stage PD-disaggregation payload (§3.4): prompt KV of a 7B-class
    # stage for a 512-token prompt: 32L x 512 x 4kvh x 128hd x K&V, bf16->f32
    pd_kv = {"k": np.random.randn(32, 512, 4, 128).astype(np.float16),
             "v": np.random.randn(32, 512, 4, 128).astype(np.float16)}
    rows = []
    for kind in ("shm", "mooncake", "inline"):
        w1, m1 = _measure(kind, t2t)
        w2, m2 = _measure(kind, t2v)
        w3, m3 = _measure(kind, pd_kv, iters=10)
        rows.append((f"table1_thinker2talker_{kind}", w1 * 1e6,
                     f"wall={w1*1e3:.3f}ms modeled_wire={m1*1e3:.3f}ms"))
        rows.append((f"table1_talker2vocoder_{kind}", w2 * 1e6,
                     f"wall={w2*1e3:.3f}ms modeled_wire={m2*1e3:.3f}ms"))
        rows.append((f"table1_pd_kv_transfer_{kind}", w3 * 1e6,
                     f"wall={w3*1e3:.3f}ms modeled_wire={m3*1e3:.3f}ms "
                     f"(64MB prompt KV)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
