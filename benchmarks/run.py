"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7,...]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,fig8,table1,two_stage,"
                         "streaming,roofline")
    args = ap.parse_args()
    sel = set(args.only.split(",")) if args.only else None

    benches = []
    if sel is None or "fig6" in sel:
        from benchmarks import bench_e2e_omni
        benches.append(("fig6", bench_e2e_omni.run))
    if sel is None or "fig7" in sel:
        from benchmarks import bench_decompose
        benches.append(("fig7", bench_decompose.run))
    if sel is None or "fig8" in sel:
        from benchmarks import bench_dit
        benches.append(("fig8", bench_dit.run))
    if sel is None or "table1" in sel:
        from benchmarks import bench_connector
        benches.append(("table1", bench_connector.run))
    if sel is None or "two_stage" in sel:
        from benchmarks import bench_two_stage
        benches.append(("two_stage", bench_two_stage.run))
    if sel is None or "streaming" in sel:
        from benchmarks import bench_streaming
        benches.append(("streaming", bench_streaming.run))
    if sel is None or "ablation" in sel:
        from benchmarks import bench_ablation
        benches.append(("ablation", bench_ablation.run))
    if sel is None or "online" in sel:
        from benchmarks import bench_online
        benches.append(("online", bench_online.run))
    if sel is None or "spec" in sel:
        from benchmarks import bench_spec_decode
        benches.append(("spec", bench_spec_decode.run))
    if sel is None or "roofline" in sel:
        from benchmarks import roofline
        benches.append(("roofline", roofline.run))

    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # keep the harness robust
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        print(f"{name}_harness_wall,{(time.perf_counter()-t0)*1e6:.0f},",
              flush=True)


if __name__ == "__main__":
    main()
