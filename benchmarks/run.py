"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  With ``--json OUT.json``
the same rows are also written as machine-readable JSON (one object per
row plus a wall-time stamp per harness) so successive PRs can diff
benchmark trajectories.

  PYTHONPATH=src python -m benchmarks.run [--only fig6,fig7,...] \
      [--json BENCH.json]
"""
from __future__ import annotations

import argparse
import json
import time


def write_json(path: str, rows, extra=None) -> None:
    """Write benchmark rows as JSON records: [{name, us_per_call, derived}]."""
    recs = [{"name": str(r[0]),
             "us_per_call": float(r[1]),
             "derived": str(r[2]) if len(r) > 2 else ""} for r in rows]
    if extra:
        recs.extend(extra)
    with open(path, "w") as f:
        json.dump(recs, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,fig8,table1,two_stage,"
                         "streaming,ablation,online,spec,prefix,roofline")
    ap.add_argument("--json", default=None, metavar="OUT.json",
                    help="also write all rows as JSON records")
    args = ap.parse_args()
    sel = set(args.only.split(",")) if args.only else None

    benches = []
    if sel is None or "fig6" in sel:
        from benchmarks import bench_e2e_omni
        benches.append(("fig6", bench_e2e_omni.run))
    if sel is None or "fig7" in sel:
        from benchmarks import bench_decompose
        benches.append(("fig7", bench_decompose.run))
    if sel is None or "fig8" in sel:
        from benchmarks import bench_dit
        benches.append(("fig8", bench_dit.run))
    if sel is None or "table1" in sel:
        from benchmarks import bench_connector
        benches.append(("table1", bench_connector.run))
    if sel is None or "two_stage" in sel:
        from benchmarks import bench_two_stage
        benches.append(("two_stage", bench_two_stage.run))
    if sel is None or "streaming" in sel:
        from benchmarks import bench_streaming
        benches.append(("streaming", bench_streaming.run))
    if sel is None or "ablation" in sel:
        from benchmarks import bench_ablation
        benches.append(("ablation", bench_ablation.run))
    if sel is None or "online" in sel:
        from benchmarks import bench_online
        benches.append(("online", bench_online.run))
    if sel is None or "spec" in sel:
        from benchmarks import bench_spec_decode
        benches.append(("spec", bench_spec_decode.run))
    if sel is None or "prefix" in sel:
        from benchmarks import bench_prefix_cache
        benches.append(("prefix", bench_prefix_cache.run))
    if sel is None or "roofline" in sel:
        from benchmarks import roofline
        benches.append(("roofline", roofline.run))

    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:  # keep the harness robust
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", flush=True)
            continue
        for r in rows:
            print(",".join(str(x) for x in r), flush=True)
        wall = (time.perf_counter() - t0) * 1e6
        print(f"{name}_harness_wall,{wall:.0f},", flush=True)
        all_rows.extend(rows)
        all_rows.append((f"{name}_harness_wall", wall, ""))
    if args.json:
        write_json(args.json, all_rows)


if __name__ == "__main__":
    main()
